//! Domain generators: [`Strategy`] implementations over the platform's
//! own input space.
//!
//! Each generator shrinks toward a *canonical do-nothing* value rather
//! than a numeric floor: fault windows become permanent (`None`),
//! compute factors become `1.0` (identity), bandwidth steps return to
//! full speed, arrival orders sort toward the identity permutation, and
//! mutated [`ServiceConfig`]s reset fields back to their base one at a
//! time. A minimal counterexample therefore reads as "the one deviation
//! that matters", which is the whole point of shrinking.

use super::strategy::{vec_of, Strategy, VecOf};
use crate::config::{
    AdaptationConfig, BandwidthEvent, ComputeEvent, FaultEvent, FaultKind, ResolutionLevel,
    ServiceConfig,
};
use crate::util::Rng;

// ---------------------------------------------------------------------------
// Fault schedules
// ---------------------------------------------------------------------------

/// One random [`FaultEvent`] across all four fault classes, mirroring
/// the hand-rolled generator the `prop_faults.rs` suite used.
#[derive(Debug, Clone, Copy)]
pub struct FaultEvents {
    cams: usize,
    nodes: usize,
}

fn window(r: &mut Rng) -> Option<f64> {
    if r.bool(0.5) {
        Some(r.range_f64(2.0, 10.0))
    } else {
        None
    }
}

impl Strategy for FaultEvents {
    type Value = FaultEvent;

    fn generate(&self, r: &mut Rng) -> FaultEvent {
        let at_sec = r.range_f64(5.0, 30.0);
        let kind = match r.range_u(0, 4) {
            0 => FaultKind::NodeCrash {
                node: r.range_u(0, self.nodes),
                down_secs: window(r),
            },
            1 => FaultKind::CameraOutage {
                camera: r.range_u(0, self.cams),
                down_secs: window(r),
            },
            2 => FaultKind::LinkPartition {
                a: r.range_u(0, self.nodes),
                b: r.range_u(0, self.nodes),
                down_secs: window(r),
            },
            _ => FaultKind::MessageLoss {
                prob: r.range_f64(0.05, 0.4),
                dur_secs: window(r),
            },
        };
        FaultEvent { at_sec, kind }
    }

    fn shrink(&self, v: &FaultEvent) -> Vec<FaultEvent> {
        let mut out = Vec::new();
        // Canonical time: the earliest the generator produces.
        if v.at_sec != 5.0 {
            out.push(FaultEvent {
                at_sec: 5.0,
                kind: v.kind,
            });
        }
        // Per-kind canonicalisation: permanent window, index 0,
        // lowest loss probability. Each candidate changes one field.
        let mut kinds = Vec::new();
        match v.kind {
            FaultKind::NodeCrash { node, down_secs } => {
                if down_secs.is_some() {
                    kinds.push(FaultKind::NodeCrash {
                        node,
                        down_secs: None,
                    });
                }
                if node != 0 {
                    kinds.push(FaultKind::NodeCrash {
                        node: 0,
                        down_secs,
                    });
                }
            }
            FaultKind::CameraOutage { camera, down_secs } => {
                if down_secs.is_some() {
                    kinds.push(FaultKind::CameraOutage {
                        camera,
                        down_secs: None,
                    });
                }
                if camera != 0 {
                    kinds.push(FaultKind::CameraOutage {
                        camera: 0,
                        down_secs,
                    });
                }
            }
            FaultKind::LinkPartition { a, b, down_secs } => {
                if down_secs.is_some() {
                    kinds.push(FaultKind::LinkPartition {
                        a,
                        b,
                        down_secs: None,
                    });
                }
                if a != 0 {
                    kinds.push(FaultKind::LinkPartition { a: 0, b, down_secs });
                }
                if b != 0 {
                    kinds.push(FaultKind::LinkPartition { a, b: 0, down_secs });
                }
            }
            FaultKind::MessageLoss { prob, dur_secs } => {
                if dur_secs.is_some() {
                    kinds.push(FaultKind::MessageLoss {
                        prob,
                        dur_secs: None,
                    });
                }
                if prob > 0.05 {
                    kinds.push(FaultKind::MessageLoss {
                        prob: 0.05,
                        dur_secs,
                    });
                }
            }
        }
        out.extend(kinds.into_iter().map(|kind| FaultEvent {
            at_sec: v.at_sec,
            kind,
        }));
        out
    }
}

/// A fault schedule of up to `max_events` events over `cams` cameras
/// and `nodes` cluster nodes; shrinks toward the empty schedule.
pub fn fault_schedule(max_events: usize, cams: usize, nodes: usize) -> VecOf<FaultEvents> {
    vec_of(FaultEvents { cams, nodes }, 0, max_events)
}

// ---------------------------------------------------------------------------
// Compute / bandwidth dynamism schedules
// ---------------------------------------------------------------------------

/// One [`ComputeEvent`]; shrinks toward the identity step
/// (`factor = 1.0`, all nodes, earliest time).
#[derive(Debug, Clone, Copy)]
pub struct ComputeEvents {
    nodes: usize,
}

impl Strategy for ComputeEvents {
    type Value = ComputeEvent;

    fn generate(&self, r: &mut Rng) -> ComputeEvent {
        ComputeEvent {
            at_sec: r.range_f64(1.0, 40.0),
            node: if r.bool(0.5) {
                Some(r.range_u(0, self.nodes))
            } else {
                None
            },
            factor: r.range_f64(0.25, 8.0),
        }
    }

    fn shrink(&self, v: &ComputeEvent) -> Vec<ComputeEvent> {
        let mut out = Vec::new();
        if v.factor != 1.0 {
            out.push(ComputeEvent { factor: 1.0, ..*v });
        }
        if v.node.is_some() {
            out.push(ComputeEvent { node: None, ..*v });
        }
        if v.at_sec != 1.0 {
            out.push(ComputeEvent { at_sec: 1.0, ..*v });
        }
        out
    }
}

/// A compute-dynamism schedule of up to `max_events` steps over
/// `nodes` cluster nodes; shrinks toward the empty schedule.
pub fn compute_schedule(max_events: usize, nodes: usize) -> VecOf<ComputeEvents> {
    vec_of(ComputeEvents { nodes }, 0, max_events)
}

/// One [`BandwidthEvent`]; shrinks toward full fabric speed at the
/// earliest time.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthEvents;

impl Strategy for BandwidthEvents {
    type Value = BandwidthEvent;

    fn generate(&self, r: &mut Rng) -> BandwidthEvent {
        BandwidthEvent {
            at_sec: r.range_f64(1.0, 40.0),
            bandwidth_bps: r.range_f64(1e7, 1e9),
        }
    }

    fn shrink(&self, v: &BandwidthEvent) -> Vec<BandwidthEvent> {
        let mut out = Vec::new();
        if v.bandwidth_bps != 1e9 {
            out.push(BandwidthEvent {
                bandwidth_bps: 1e9,
                ..*v
            });
        }
        if v.at_sec != 1.0 {
            out.push(BandwidthEvent { at_sec: 1.0, ..*v });
        }
        out
    }
}

/// A bandwidth schedule of up to `max_events` steps; shrinks toward
/// the empty schedule.
pub fn bandwidth_schedule(max_events: usize) -> VecOf<BandwidthEvents> {
    vec_of(BandwidthEvents, 0, max_events)
}

// ---------------------------------------------------------------------------
// Adaptation-plane configurations
// ---------------------------------------------------------------------------

/// A random [`AdaptationConfig`]: a 1–4-rung resolution ladder (rung 0
/// always native, deeper rungs monotonically cheaper and coarser),
/// hysteresis band and cooldown drawn from the controller's sane
/// ranges, controller switched on. Shrinks toward the canonical
/// do-nothing configuration — the *enabled identity ladder* — one
/// deviation at a time: first drop the deepest rung, then neutralise
/// one non-native rung back to native, then reset one policy knob. A
/// minimal counterexample therefore names the single rung or knob that
/// breaks the property, and the shrink floor itself proves the
/// identity-ladder contract (enabled + identity ⇒ inert).
#[derive(Debug, Clone, Copy)]
pub struct AdaptationConfigs;

/// Adaptation-config strategy (enabled controller, 1–4 rungs).
pub fn adaptation_config() -> AdaptationConfigs {
    AdaptationConfigs
}

/// The canonical shrink floor: controller on, identity ladder, default
/// policy knobs. `is_identity()` holds, so the plane is inert.
fn adapt_floor() -> AdaptationConfig {
    AdaptationConfig {
        enabled: true,
        ..AdaptationConfig::default()
    }
}

impl Strategy for AdaptationConfigs {
    type Value = AdaptationConfig;

    fn generate(&self, r: &mut Rng) -> AdaptationConfig {
        let rungs = r.range_u(1, 5);
        let mut ladder = vec![ResolutionLevel::native()];
        for _ in 1..rungs {
            let prev = *ladder.last().unwrap();
            ladder.push(ResolutionLevel {
                scale: prev.scale * r.range_f64(0.4, 0.9),
                cost: prev.cost * r.range_f64(0.4, 0.95),
                accuracy: prev.accuracy * r.range_f64(0.85, 1.0),
                stride: if r.bool(0.25) {
                    prev.stride * 2
                } else {
                    prev.stride
                },
            });
        }
        let slack_down = r.range_f64(0.05, 0.4);
        AdaptationConfig {
            enabled: true,
            ladder,
            slack_down,
            slack_up: slack_down + r.range_f64(0.1, 0.5),
            cooldown_secs: r.range_f64(0.5, 10.0),
        }
    }

    fn shrink(&self, v: &AdaptationConfig) -> Vec<AdaptationConfig> {
        let floor = adapt_floor();
        let mut out = Vec::new();
        // Drop the deepest rung first: ladder depth is usually the
        // interesting variable, and each pop strictly shortens it.
        if v.ladder.len() > 1 {
            let mut w = v.clone();
            w.ladder.pop();
            out.push(w);
        }
        // Neutralise one remaining non-native rung back to native.
        for (i, l) in v.ladder.iter().enumerate().skip(1) {
            if !l.is_native() {
                let mut w = v.clone();
                w.ladder[i] = ResolutionLevel::native();
                out.push(w);
            }
        }
        // Reset one policy knob, keeping the hysteresis band valid
        // (`slack_down < slack_up`) in every candidate.
        if v.slack_down != floor.slack_down && floor.slack_down < v.slack_up {
            out.push(AdaptationConfig {
                slack_down: floor.slack_down,
                ..v.clone()
            });
        }
        if v.slack_up != floor.slack_up && v.slack_down < floor.slack_up {
            out.push(AdaptationConfig {
                slack_up: floor.slack_up,
                ..v.clone()
            });
        }
        if v.cooldown_secs != floor.cooldown_secs {
            out.push(AdaptationConfig {
                cooldown_secs: floor.cooldown_secs,
                ..v.clone()
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// DRR weight sets
// ---------------------------------------------------------------------------

/// One DRR weight in `[1, max_weight]`; shrinks toward 1.
#[derive(Debug, Clone, Copy)]
pub struct Weight {
    max_weight: u32,
}

impl Strategy for Weight {
    type Value = u32;

    fn generate(&self, r: &mut Rng) -> u32 {
        r.range_u(1, self.max_weight as usize + 1) as u32
    }

    fn shrink(&self, v: &u32) -> Vec<u32> {
        let mut out = Vec::new();
        if *v > 1 {
            out.push(1);
            let mid = 1 + (v - 1) / 2;
            if mid != 1 && mid != *v {
                out.push(mid);
            }
        }
        out
    }
}

/// A DRR weight set for `min_queries..=max_queries` queries with
/// weights in `[1, max_weight]`; shrinks toward fewer queries with
/// unit weights.
pub fn drr_weights(min_queries: usize, max_queries: usize, max_weight: u32) -> VecOf<Weight> {
    vec_of(Weight { max_weight }, min_queries, max_queries)
}

// ---------------------------------------------------------------------------
// Event-arrival orders
// ---------------------------------------------------------------------------

/// A permutation of `0..n` modelling an arrival order; shrinks toward
/// the identity permutation one transposition at a time.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalOrder {
    n: usize,
}

/// Arrival-order strategy over `n` events.
pub fn arrival_order(n: usize) -> ArrivalOrder {
    ArrivalOrder { n }
}

impl Strategy for ArrivalOrder {
    type Value = Vec<usize>;

    fn generate(&self, r: &mut Rng) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.n).collect();
        r.shuffle(&mut v);
        v
    }

    fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
        let identity: Vec<usize> = (0..v.len()).collect();
        if *v == identity {
            return Vec::new();
        }
        let mut out = vec![identity];
        // One transposition toward identity: put the smallest
        // out-of-place value where it belongs. Each accepted step
        // strictly increases the count of fixed points, so the walk
        // terminates at the identity.
        if let Some(i) = v.iter().enumerate().position(|(i, &x)| x != i) {
            if let Some(j) = v.iter().position(|&x| x == i) {
                let mut w = v.clone();
                w.swap(i, j);
                out.push(w);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Shard plans
// ---------------------------------------------------------------------------

/// A sharded-execution plan for the DES engines: shard count `K`, the
/// merge backend (inline vs one worker thread per shard), and a camera
/// count that can drop *below* `K` to force degenerate single-vertex
/// shards in the partition. Shrinks toward the canonical unsharded
/// plan (`shards = 1`, `threads = 0`, full-size workload) one field at
/// a time, so a minimal counterexample names whether the shard count,
/// the threaded backend, or the degenerate layout breaks the property.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Shard count `K ∈ [1, 8]`.
    pub shards: usize,
    /// Merge-backend worker threads (0 = inline; `shards` = threaded).
    pub threads: usize,
    /// Cameras in the generated workload. May be smaller than
    /// `shards`; [`crate::roadnet::partition()`] then clamps `K` to the
    /// vertex count and every shard is a single boundary vertex.
    pub cameras: usize,
}

/// Camera counts the generator draws from: a degenerate handful
/// (below the largest `K`, forcing single-vertex shards), a
/// boundary-heavy small town, and the canonical full-size workload.
const SHARD_CAMERA_SIZES: [usize; 3] = [3, 12, 40];

/// Strategy over [`ShardPlan`]s.
#[derive(Debug, Clone, Copy)]
pub struct ShardPlans;

/// Shard-plan strategy (`K ∈ [1, 8]`, inline or threaded backend,
/// degenerate to full-size workloads).
pub fn shard_plan() -> ShardPlans {
    ShardPlans
}

impl Strategy for ShardPlans {
    type Value = ShardPlan;

    fn generate(&self, r: &mut Rng) -> ShardPlan {
        let shards = r.range_u(1, 9);
        let threads = if r.bool(0.5) { shards } else { 0 };
        let cameras =
            SHARD_CAMERA_SIZES[r.range_u(0, SHARD_CAMERA_SIZES.len())];
        ShardPlan {
            shards,
            threads,
            cameras,
        }
    }

    fn shrink(&self, v: &ShardPlan) -> Vec<ShardPlan> {
        let mut out = Vec::new();
        // Backend first: an inline repro of a threaded failure is the
        // more valuable counterexample.
        if v.threads != 0 {
            out.push(ShardPlan { threads: 0, ..*v });
        }
        if v.shards > 1 {
            out.push(ShardPlan {
                shards: 1,
                threads: v.threads.min(1),
                ..*v
            });
            let mid = 1 + (v.shards - 1) / 2;
            if mid != 1 && mid != v.shards {
                out.push(ShardPlan {
                    shards: mid,
                    threads: v.threads.min(mid),
                    ..*v
                });
            }
        }
        let full = SHARD_CAMERA_SIZES[SHARD_CAMERA_SIZES.len() - 1];
        if v.cameras != full {
            out.push(ShardPlan {
                cameras: full,
                ..*v
            });
        }
        out
    }
}

// ---------------------------------------------------------------------------
// ServiceConfig mutations
// ---------------------------------------------------------------------------

/// Random timing mutations of a base [`ServiceConfig`]: each ξ-model
/// field is scaled by a factor in `[0.5, 2.0)` and jitter is drawn in
/// `[0, 0.3)`. Shrinking resets one field at a time back to the base,
/// so a minimal counterexample names the single knob that breaks the
/// property.
#[derive(Debug, Clone)]
pub struct ServiceConfigMutations {
    base: ServiceConfig,
}

/// Mutation strategy around `base`.
pub fn service_config_mutations(base: ServiceConfig) -> ServiceConfigMutations {
    ServiceConfigMutations { base }
}

impl Strategy for ServiceConfigMutations {
    type Value = ServiceConfig;

    fn generate(&self, r: &mut Rng) -> ServiceConfig {
        let mut c = self.base.clone();
        c.fc_ms = self.base.fc_ms * r.range_f64(0.5, 2.0);
        c.va_alpha_ms = self.base.va_alpha_ms * r.range_f64(0.5, 2.0);
        c.va_beta_ms = self.base.va_beta_ms * r.range_f64(0.5, 2.0);
        c.cr_alpha_ms = self.base.cr_alpha_ms * r.range_f64(0.5, 2.0);
        c.cr_beta_ms = self.base.cr_beta_ms * r.range_f64(0.5, 2.0);
        c.tl_ms = self.base.tl_ms * r.range_f64(0.5, 2.0);
        c.jitter = r.range_f64(0.0, 0.3);
        c
    }

    fn shrink(&self, v: &ServiceConfig) -> Vec<ServiceConfig> {
        let mut out = Vec::new();
        let fields: [(fn(&ServiceConfig) -> f64, fn(&mut ServiceConfig, f64)); 7] = [
            (|c| c.fc_ms, |c, x| c.fc_ms = x),
            (|c| c.va_alpha_ms, |c, x| c.va_alpha_ms = x),
            (|c| c.va_beta_ms, |c, x| c.va_beta_ms = x),
            (|c| c.cr_alpha_ms, |c, x| c.cr_alpha_ms = x),
            (|c| c.cr_beta_ms, |c, x| c.cr_beta_ms = x),
            (|c| c.tl_ms, |c, x| c.tl_ms = x),
            (|c| c.jitter, |c, x| c.jitter = x),
        ];
        for (get, set) in fields {
            if get(v) != get(&self.base) {
                let mut w = v.clone();
                set(&mut w, get(&self.base));
                out.push(w);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng;

    #[test]
    fn fault_schedule_is_deterministic_and_shrinks_to_empty() {
        let s = fault_schedule(4, 50, 10);
        let a = s.generate(&mut rng(7, 0));
        let b = s.generate(&mut rng(7, 0));
        assert_eq!(a, b);
        if !a.is_empty() {
            assert_eq!(s.shrink(&a)[0], Vec::new());
        }
    }

    #[test]
    fn fault_event_shrink_canonicalises_one_field_per_candidate() {
        let s = FaultEvents { cams: 50, nodes: 10 };
        let v = FaultEvent {
            at_sec: 22.5,
            kind: FaultKind::NodeCrash {
                node: 7,
                down_secs: Some(4.0),
            },
        };
        let cands = s.shrink(&v);
        assert!(cands.contains(&FaultEvent {
            at_sec: 5.0,
            kind: v.kind
        }));
        assert!(cands.contains(&FaultEvent {
            at_sec: 22.5,
            kind: FaultKind::NodeCrash {
                node: 7,
                down_secs: None
            }
        }));
        // Fully canonical event is minimal.
        let min = FaultEvent {
            at_sec: 5.0,
            kind: FaultKind::NodeCrash {
                node: 0,
                down_secs: None,
            },
        };
        assert!(s.shrink(&min).is_empty());
    }

    #[test]
    fn compute_event_shrinks_toward_identity_factor() {
        let s = ComputeEvents { nodes: 10 };
        let v = ComputeEvent {
            at_sec: 12.0,
            node: Some(3),
            factor: 4.0,
        };
        let cands = s.shrink(&v);
        assert!((cands[0].factor - 1.0).abs() < 1e-12);
        let min = ComputeEvent {
            at_sec: 1.0,
            node: None,
            factor: 1.0,
        };
        assert!(s.shrink(&min).is_empty());
    }

    #[test]
    fn arrival_order_is_a_permutation_and_sorts_toward_identity() {
        let s = arrival_order(8);
        let v = s.generate(&mut rng(3, 0));
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
        // Walk the one-transposition chain with an always-failing
        // property (skipping the aggressive identity candidate): each
        // step fixes at least one more point, so it reaches identity.
        let identity: Vec<usize> = (0..8).collect();
        let mut cur = v;
        let mut steps = 0;
        while cur != identity {
            let cands = s.shrink(&cur);
            cur = cands.last().unwrap().clone();
            steps += 1;
            assert!(steps <= 8, "transposition chain too long");
        }
        assert!(s.shrink(&identity).is_empty());
    }

    #[test]
    fn shard_plan_generates_in_range_and_shrinks_to_unsharded() {
        let s = shard_plan();
        let a = s.generate(&mut rng(9, 0));
        let b = s.generate(&mut rng(9, 0));
        assert_eq!(a, b, "generator is seed-deterministic");
        for case in 0..64 {
            let v = s.generate(&mut rng(9, case));
            assert!((1..=8).contains(&v.shards), "{v:?}");
            assert!(v.threads == 0 || v.threads == v.shards, "{v:?}");
            assert!(SHARD_CAMERA_SIZES.contains(&v.cameras), "{v:?}");
        }
        // Every shrink chain terminates at the canonical unsharded
        // plan, and the walk changes at most ~log K + 2 steps.
        let mut cur = ShardPlan {
            shards: 8,
            threads: 8,
            cameras: 3,
        };
        let min = ShardPlan {
            shards: 1,
            threads: 0,
            cameras: 40,
        };
        let mut steps = 0;
        while cur != min {
            let cands = s.shrink(&cur);
            assert!(!cands.is_empty(), "stuck at {cur:?}");
            cur = *cands.last().unwrap();
            steps += 1;
            assert!(steps <= 12, "shrink chain too long at {cur:?}");
        }
        assert!(s.shrink(&min).is_empty(), "canonical plan is minimal");
    }

    #[test]
    fn shard_plan_can_force_degenerate_single_camera_shards() {
        let s = shard_plan();
        let degenerate = (0..256).any(|case| {
            let v = s.generate(&mut rng(1, case));
            v.shards > v.cameras
        });
        assert!(
            degenerate,
            "generator must sometimes draw K above the camera count"
        );
    }

    #[test]
    fn service_config_shrink_resets_one_field_at_a_time() {
        let base = ServiceConfig::default();
        let s = service_config_mutations(base.clone());
        let v = s.generate(&mut rng(11, 0));
        for w in s.shrink(&v) {
            let diffs = [
                w.fc_ms != v.fc_ms,
                w.va_alpha_ms != v.va_alpha_ms,
                w.va_beta_ms != v.va_beta_ms,
                w.cr_alpha_ms != v.cr_alpha_ms,
                w.cr_beta_ms != v.cr_beta_ms,
                w.tl_ms != v.tl_ms,
                w.jitter != v.jitter,
            ];
            assert_eq!(diffs.iter().filter(|&&d| d).count(), 1);
        }
        // The base itself is minimal.
        assert!(s.shrink(&base).is_empty());
    }

    #[test]
    fn adaptation_config_generates_valid_ladders() {
        let s = adaptation_config();
        let a = s.generate(&mut rng(13, 0));
        let b = s.generate(&mut rng(13, 0));
        assert_eq!(a, b, "generator is seed-deterministic");
        for case in 0..64 {
            let v = s.generate(&mut rng(13, case));
            assert!(v.enabled);
            assert!((1..=4).contains(&v.ladder.len()), "{v:?}");
            assert!(v.ladder[0].is_native(), "{v:?}");
            assert!(v.slack_down < v.slack_up, "{v:?}");
            assert!(v.cooldown_secs > 0.0, "{v:?}");
            // Deeper rungs are monotonically cheaper and coarser.
            for w in v.ladder.windows(2) {
                assert!(w[1].scale < w[0].scale, "{v:?}");
                assert!(w[1].cost < w[0].cost, "{v:?}");
                assert!(w[1].accuracy <= w[0].accuracy, "{v:?}");
                assert!(w[1].stride >= w[0].stride, "{v:?}");
            }
        }
    }

    #[test]
    fn adaptation_config_shrinks_to_enabled_identity_ladder() {
        let s = adaptation_config();
        let floor = adapt_floor();
        assert!(floor.is_identity(), "shrink floor must be inert");
        assert!(s.shrink(&floor).is_empty(), "floor is minimal");
        // Every shrink step keeps the hysteresis band valid and the
        // walk terminates at the floor.
        for case in 0..16 {
            let mut cur = s.generate(&mut rng(13, case));
            let mut steps = 0;
            while cur != floor {
                let cands = s.shrink(&cur);
                assert!(!cands.is_empty(), "stuck at {cur:?}");
                for c in &cands {
                    assert!(c.slack_down < c.slack_up, "{c:?}");
                }
                cur = cands[0].clone();
                steps += 1;
                assert!(steps <= 16, "shrink chain too long at {cur:?}");
            }
        }
    }

    #[test]
    fn drr_weights_shrink_toward_unit() {
        let s = drr_weights(2, 6, 5);
        let v = s.generate(&mut rng(5, 0));
        assert!(v.len() >= 2 && v.len() <= 6);
        assert!(v.iter().all(|&w| (1..=5).contains(&w)));
        let w = Weight { max_weight: 5 };
        assert_eq!(w.shrink(&1), Vec::<u32>::new());
        assert_eq!(w.shrink(&5)[0], 1);
    }
}
