//! Case runner: fresh generation, greedy shrinking, seed replay, and
//! persisted regression seeds.
//!
//! Properties are `Fn(&Value) -> Result<(), String>` — `Err` carries
//! the violation message, so the final report shows *why* the minimal
//! counterexample fails, not just what it is. [`check`] is the
//! test-facing entry point: it replays any persisted seeds for the
//! property from `rust/tests/regressions/<name>.seeds`, then runs the
//! configured number of fresh cases, shrinking and panicking with a
//! replay recipe on the first failure. [`find_failure`] is the same
//! loop without the panic, which is what the planted-bug self-tests
//! use to inspect the minimal counterexample programmatically.

use std::fmt::Debug;
use std::path::{Path, PathBuf};

use super::strategy::Strategy;
use crate::util::rng;

/// How many fresh cases to run and where the RNG streams start.
#[derive(Debug, Clone, Copy)]
pub struct CheckConfig {
    /// Number of fresh generated cases (`ANVESHAK_CHECK_CASES`
    /// overrides).
    pub cases: u64,
    /// Base seed; case `i` draws from `util::rng(seed, i)`
    /// (`ANVESHAK_CHECK_SEED` overrides).
    pub seed: u64,
    /// Cap on accepted shrink steps, a safety net on top of the
    /// combinators' own termination guarantees.
    pub max_shrink_steps: u64,
}

impl Default for CheckConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC43C_2019,
            max_shrink_steps: 10_000,
        }
    }
}

impl CheckConfig {
    /// A config with a different case count, keeping the default seed.
    pub fn with_cases(cases: u64) -> Self {
        Self {
            cases,
            ..Self::default()
        }
    }
}

/// A failing case, both as generated and after shrinking.
#[derive(Debug, Clone)]
pub struct Failure<T> {
    /// Base seed of the run that found it.
    pub seed: u64,
    /// Case index within that run; `(seed, case)` replays it.
    pub case: u64,
    /// The value as generated, before any shrinking.
    pub original: T,
    /// Property error for the original value.
    pub original_error: String,
    /// The shrunk, minimal counterexample.
    pub minimal: T,
    /// Property error for the minimal counterexample.
    pub minimal_error: String,
    /// Number of accepted shrink steps between the two.
    pub shrink_steps: u64,
}

/// Regenerate the exact value that `(seed, case)` produced — the
/// deterministic-replay primitive behind the printed recipe.
pub fn generate_case<S: Strategy>(strat: &S, seed: u64, case: u64) -> S::Value {
    strat.generate(&mut rng(seed, case))
}

/// Greedily walk `strat`'s shrink candidates from a failing value to a
/// fixpoint, keeping the first candidate that still fails.
fn shrink_to_minimal<S, P>(
    strat: &S,
    value: S::Value,
    error: String,
    prop: &P,
    max_steps: u64,
) -> (S::Value, String, u64)
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    let mut cur = value;
    let mut cur_err = error;
    let mut steps = 0u64;
    'outer: while steps < max_steps {
        for cand in strat.shrink(&cur) {
            if let Err(e) = prop(&cand) {
                cur = cand;
                cur_err = e;
                steps += 1;
                continue 'outer;
            }
        }
        break; // every candidate passes: cur is minimal
    }
    (cur, cur_err, steps)
}

fn run_one<S, P>(
    strat: &S,
    prop: &P,
    seed: u64,
    case: u64,
    max_shrink_steps: u64,
) -> Option<Failure<S::Value>>
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    let value = generate_case(strat, seed, case);
    match prop(&value) {
        Ok(()) => None,
        Err(e) => {
            let (minimal, minimal_error, shrink_steps) =
                shrink_to_minimal(strat, value.clone(), e.clone(), prop, max_shrink_steps);
            Some(Failure {
                seed,
                case,
                original: value,
                original_error: e,
                minimal,
                minimal_error,
                shrink_steps,
            })
        }
    }
}

/// Run fresh cases and return the first (shrunk) failure, or `None` if
/// every case passes. No panic, no regression replay — the primitive
/// the planted-bug self-tests build on.
pub fn find_failure<S, P>(cfg: &CheckConfig, strat: &S, prop: P) -> Option<Failure<S::Value>>
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        if let Some(f) = run_one(strat, &prop, cfg.seed, case, cfg.max_shrink_steps) {
            return Some(f);
        }
    }
    None
}

/// Directory holding persisted regression seeds, one
/// `<property-name>.seeds` file per property.
pub fn regressions_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/regressions")
}

/// Parse `<name>.seeds`: one `seed case` pair per line (decimal),
/// `#`-comments and blank lines ignored. Missing file means no
/// regressions, not an error.
pub fn regression_seeds(name: &str) -> Vec<(u64, u64)> {
    let path = regressions_dir().join(format!("{name}.seeds"));
    let Ok(text) = std::fs::read_to_string(&path) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        match (
            it.next().and_then(|s| s.parse::<u64>().ok()),
            it.next().and_then(|s| s.parse::<u64>().ok()),
        ) {
            (Some(seed), Some(case)) => out.push((seed, case)),
            _ => panic!("malformed line in {}: {line:?}", path.display()),
        }
    }
    out
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok()?.trim().parse().ok()
}

fn report<T: Debug>(name: &str, f: &Failure<T>, from_regression: bool) -> String {
    let source = if from_regression {
        "persisted regression seed"
    } else {
        "fresh case"
    };
    format!(
        "property `{name}` failed ({source})\n\
         \x20 replay:   ANVESHAK_CHECK_SEED={} with case {} (or add `{} {}` to \
         rust/tests/regressions/{name}.seeds)\n\
         \x20 original: {:?}\n\
         \x20           {}\n\
         \x20 minimal:  {:?}  ({} shrink steps)\n\
         \x20           {}",
        f.seed, f.case, f.seed, f.case, f.original, f.original_error, f.minimal, f.shrink_steps,
        f.minimal_error,
    )
}

/// Test-facing entry point: replay persisted regression seeds for
/// `name`, then run `cfg.cases` fresh cases; on any failure, shrink to
/// a minimal counterexample and panic with a deterministic replay
/// recipe. `ANVESHAK_CHECK_SEED` / `ANVESHAK_CHECK_CASES` override the
/// config at run time.
pub fn check<S, P>(name: &str, cfg: &CheckConfig, strat: &S, prop: P)
where
    S: Strategy,
    P: Fn(&S::Value) -> Result<(), String>,
{
    let mut cfg = *cfg;
    if let Some(seed) = env_u64("ANVESHAK_CHECK_SEED") {
        cfg.seed = seed;
    }
    if let Some(cases) = env_u64("ANVESHAK_CHECK_CASES") {
        cfg.cases = cases;
    }
    for (seed, case) in regression_seeds(name) {
        if let Some(f) = run_one(strat, &prop, seed, case, cfg.max_shrink_steps) {
            panic!("{}", report(name, &f, true));
        }
    }
    if let Some(f) = find_failure(&cfg, strat, &prop) {
        panic!("{}", report(name, &f, false));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::strategy::{range_u, vec_of};

    // The textbook planted bug: "no element may reach 50". The unique
    // minimal counterexample is the one-element vec [50]. The property
    // signature must match `Fn(&S::Value)` exactly, hence `&Vec`.
    #[allow(clippy::ptr_arg)]
    fn no_element_reaches_50(v: &Vec<usize>) -> Result<(), String> {
        match v.iter().find(|&&x| x >= 50) {
            Some(x) => Err(format!("element {x} >= 50")),
            None => Ok(()),
        }
    }

    #[test]
    fn shrinks_to_the_unique_minimal_counterexample() {
        let strat = vec_of(range_u(0, 100), 0, 12);
        let cfg = CheckConfig::default();
        let f = find_failure(&cfg, &strat, no_element_reaches_50)
            .expect("a >=50 element appears well within 64 cases");
        assert_eq!(f.minimal, vec![50], "greedy shrink must reach [50]");
        assert!(f.minimal_error.contains("50"));
    }

    #[test]
    fn replay_regenerates_the_failing_case_bit_for_bit() {
        let strat = vec_of(range_u(0, 100), 0, 12);
        let cfg = CheckConfig::default();
        let f = find_failure(&cfg, &strat, no_element_reaches_50).expect("failure");
        let replayed = generate_case(&strat, f.seed, f.case);
        assert_eq!(replayed, f.original);
        // And the whole search is deterministic end to end.
        let f2 = find_failure(&cfg, &strat, no_element_reaches_50).expect("failure");
        assert_eq!(f2.case, f.case);
        assert_eq!(f2.minimal, f.minimal);
        assert_eq!(f2.shrink_steps, f.shrink_steps);
    }

    #[test]
    fn passing_property_finds_no_failure() {
        let strat = vec_of(range_u(0, 100), 0, 12);
        let cfg = CheckConfig::with_cases(32);
        assert!(find_failure(&cfg, &strat, |_| Ok(())).is_none());
    }

    #[test]
    fn shrink_step_cap_is_respected() {
        let strat = range_u(0, 1_000_000);
        let cfg = CheckConfig {
            cases: 4,
            seed: 1,
            max_shrink_steps: 3,
        };
        // Property that always fails: shrinking would walk to 0, but
        // the cap stops it after 3 accepted steps.
        let f = find_failure(&cfg, &strat, |_| Err("always".into())).expect("failure");
        assert!(f.shrink_steps <= 3);
    }

    #[test]
    fn regression_file_parsing_ignores_comments_and_blanks() {
        // Missing file: silently empty.
        assert!(regression_seeds("no-such-property-file").is_empty());
    }
}
