//! In-repo property-testing harness, runtime invariant checkers, and
//! repo-invariant lint pass.
//!
//! Zero-dependency by design, following the `rust/vendor/anyhow` shim
//! precedent: everything here is plain std + [`crate::util::Rng`], so
//! the verification layer can never rot behind an unavailable crate.
//! Three coupled pieces live in this module:
//!
//! 1. **Generators + shrinking** ([`strategy`], [`runner`], [`domain`]):
//!    a proptest-style [`Strategy`] trait with combinators for ranges,
//!    choices, vecs and tuples, plus domain generators for the
//!    platform's own input space (fault schedules, compute/bandwidth
//!    events, DRR weight sets, arrival orders, [`crate::config::ServiceConfig`]
//!    mutations). On failure the [`runner`] greedily shrinks to a
//!    *minimal* counterexample and prints a `seed`/`case` pair that
//!    replays it deterministically; pairs worth keeping are persisted
//!    under `rust/tests/regressions/` and replayed before every fresh
//!    run.
//!
//! 2. **Runtime invariant checkers** behind the `strict-invariants`
//!    feature: the [`strict_assert!`] macro guards `assert!`-grade
//!    checks inside the hot engines (event-slab aliasing, budget-ring
//!    key hygiene, drop-gate exemptions, feedback exactly-once, ledger
//!    conservation). The checks compile in every build — `cfg!` keeps
//!    them type-checked — but the branch is constant-false unless the
//!    feature is on, so the default build pays nothing.
//!
//! 3. **Repo-invariant lint** ([`lint`]): a plain source scan over
//!    `rust/src/` enforcing invariants rustc/clippy cannot express
//!    (trace gating, wall-clock bans in DES paths, deterministic map
//!    types, the no-`unsafe` rule). Run it as `harness lint`; CI runs
//!    it as a blocking job.

pub mod domain;
pub mod lint;
pub mod runner;
pub mod strategy;

pub use lint::{lint_repo, lint_tree, LintReport, Violation};
pub use runner::{check, find_failure, generate_case, CheckConfig, Failure};
pub use strategy::{
    choice, just, range_f64, range_i64, range_u, vec_of, Choice, Just, RangeF64, RangeI64, RangeU,
    Strategy, VecOf,
};

/// `assert!` that only fires when the `strict-invariants` feature is
/// enabled.
///
/// Unlike an `#[cfg(...)]`-gated block, the body is *always* compiled
/// and type-checked (`cfg!` is a const boolean, not conditional
/// compilation), so the default CI build catches bit-rot in the check
/// expressions; the optimizer removes the constant-false branch, so
/// the default build pays nothing at runtime. Invoke as
/// `crate::strict_assert!(cond, "message {}", detail)` from anywhere
/// in the crate.
#[macro_export]
macro_rules! strict_assert {
    ($($arg:tt)*) => {
        if cfg!(feature = "strict-invariants") {
            assert!($($arg)*);
        }
    };
}
