//! The [`Strategy`] trait and generic combinators.
//!
//! A `Strategy` knows two things: how to *generate* a value from a
//! seeded [`Rng`], and how to *shrink* a failing value toward simpler
//! candidates. Shrinking is value-based (proptest's model, not
//! QuickCheck's type-based one): `shrink(&v)` proposes a short, ordered
//! list of strictly-simpler candidates — most aggressive first — and
//! the runner greedily walks to a fixpoint, keeping the first candidate
//! that still fails the property. Every combinator's candidates are
//! strictly smaller under a well-founded order (shorter vec, value
//! closer to the range floor, earlier choice index), so the walk always
//! terminates even without the runner's step cap.

use std::fmt::Debug;

use crate::util::Rng;

/// A generator + shrinker for values of type [`Strategy::Value`].
pub trait Strategy {
    /// The type of value this strategy produces.
    type Value: Clone + Debug;

    /// Produce one value from the given RNG. Must be deterministic in
    /// the RNG stream: the same seeded `Rng` yields the same value,
    /// which is what makes printed `seed`/`case` pairs replayable.
    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Propose strictly-simpler candidates for a failing value, most
    /// aggressive first. An empty vec means the value is minimal.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Always generates a clone of one fixed value; never shrinks.
#[derive(Debug, Clone)]
pub struct Just<T>(T);

/// Strategy for a constant — useful as a tuple member when only the
/// other members should vary.
pub fn just<T: Clone + Debug>(value: T) -> Just<T> {
    Just(value)
}

impl<T: Clone + Debug> Strategy for Just<T> {
    type Value = T;

    fn generate(&self, _rng: &mut Rng) -> T {
        self.0.clone()
    }
}

/// Uniform `usize` in the half-open range `[lo, hi)`, shrinking toward
/// `lo`.
#[derive(Debug, Clone, Copy)]
pub struct RangeU {
    lo: usize,
    hi: usize,
}

/// `usize` in `[lo, hi)` (half-open, matching [`Rng::range_u`]).
pub fn range_u(lo: usize, hi: usize) -> RangeU {
    assert!(lo < hi, "empty range {lo}..{hi}");
    RangeU { lo, hi }
}

impl Strategy for RangeU {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range_u(self.lo, self.hi)
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let v = *value;
        let mut out = Vec::new();
        if v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (v - self.lo) / 2;
            if mid != self.lo && mid != v {
                out.push(mid);
            }
            if v - 1 != self.lo && v - 1 != mid {
                out.push(v - 1);
            }
        }
        out
    }
}

/// Uniform `i64` in the inclusive range `[lo, hi]`, shrinking toward
/// `lo` (via `0` when the range spans it).
#[derive(Debug, Clone, Copy)]
pub struct RangeI64 {
    lo: i64,
    hi: i64,
}

/// `i64` in `[lo, hi]` (inclusive, matching [`Rng::range_i64`]).
pub fn range_i64(lo: i64, hi: i64) -> RangeI64 {
    assert!(lo <= hi, "empty range {lo}..={hi}");
    RangeI64 { lo, hi }
}

impl Strategy for RangeI64 {
    type Value = i64;

    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.range_i64(self.lo, self.hi)
    }

    fn shrink(&self, value: &i64) -> Vec<i64> {
        let v = *value;
        let mut out = Vec::new();
        if v > self.lo {
            out.push(self.lo);
            if self.lo < 0 && v > 0 {
                out.push(0);
            }
            let mid = self.lo + (v - self.lo) / 2;
            if mid != self.lo && mid != v && !out.contains(&mid) {
                out.push(mid);
            }
            if v - 1 != self.lo && !out.contains(&(v - 1)) {
                out.push(v - 1);
            }
        }
        out
    }
}

/// Uniform `f64` in `[lo, hi)`, shrinking toward `lo` by bisection.
#[derive(Debug, Clone, Copy)]
pub struct RangeF64 {
    lo: f64,
    hi: f64,
}

/// `f64` in `[lo, hi)` (half-open, matching [`Rng::range_f64`]).
pub fn range_f64(lo: f64, hi: f64) -> RangeF64 {
    assert!(lo < hi, "empty range {lo}..{hi}");
    RangeF64 { lo, hi }
}

impl Strategy for RangeF64 {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }

    fn shrink(&self, value: &f64) -> Vec<f64> {
        let v = *value;
        let mut out = Vec::new();
        // Bisect toward lo; stop proposing once the distance is tiny so
        // the fixpoint walk cannot stall on float dust.
        if v - self.lo > 1e-9 {
            out.push(self.lo);
            let mid = self.lo + (v - self.lo) / 2.0;
            if mid - self.lo > 1e-9 && v - mid > 1e-9 {
                out.push(mid);
            }
        }
        out
    }
}

/// One of a fixed set of options, shrinking toward earlier options.
#[derive(Debug, Clone)]
pub struct Choice<T> {
    options: Vec<T>,
}

/// Pick uniformly among `options`; shrinking moves toward the front of
/// the list, so put the simplest option first.
pub fn choice<T: Clone + Debug + PartialEq>(options: Vec<T>) -> Choice<T> {
    assert!(!options.is_empty(), "choice of zero options");
    Choice { options }
}

impl<T: Clone + Debug + PartialEq> Strategy for Choice<T> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        self.options[rng.range_u(0, self.options.len())].clone()
    }

    fn shrink(&self, value: &T) -> Vec<T> {
        match self.options.iter().position(|o| o == value) {
            Some(i) => self.options[..i].to_vec(),
            None => Vec::new(),
        }
    }
}

/// A vec of values from an element strategy, with length in
/// `[min_len, max_len]`. Shrinks by truncating to `min_len`, halving
/// the length, dropping single elements, then shrinking elements in
/// place.
#[derive(Debug, Clone)]
pub struct VecOf<S> {
    elem: S,
    min_len: usize,
    max_len: usize,
}

/// Vec of `elem`-generated values with length in `[min_len, max_len]`
/// (inclusive on both ends).
pub fn vec_of<S: Strategy>(elem: S, min_len: usize, max_len: usize) -> VecOf<S> {
    assert!(min_len <= max_len, "empty length range {min_len}..={max_len}");
    VecOf {
        elem,
        min_len,
        max_len,
    }
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;

    fn generate(&self, rng: &mut Rng) -> Vec<S::Value> {
        let n = if self.min_len == self.max_len {
            self.min_len
        } else {
            rng.range_u(self.min_len, self.max_len + 1)
        };
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, value: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        if value.len() > self.min_len {
            // Most aggressive first: straight to the shortest allowed
            // prefix, then half way there, then each single removal.
            out.push(value[..self.min_len].to_vec());
            let half = self.min_len + (value.len() - self.min_len) / 2;
            if half != self.min_len && half != value.len() {
                out.push(value[..half].to_vec());
            }
            for i in 0..value.len() {
                let mut w = value.clone();
                w.remove(i);
                out.push(w);
            }
        }
        for i in 0..value.len() {
            for cand in self.elem.shrink(&value[i]) {
                let mut w = value.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Tuples of strategies generate tuples of values; shrinking varies one
/// component at a time.
impl<A: Strategy, B: Strategy> Strategy for (A, B) {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b));
        }
        out
    }
}

impl<A: Strategy, B: Strategy, C: Strategy> Strategy for (A, B, C) {
    type Value = (A::Value, B::Value, C::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (
            self.0.generate(rng),
            self.1.generate(rng),
            self.2.generate(rng),
        )
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone(), value.2.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b, value.2.clone()));
        }
        for c in self.2.shrink(&value.2) {
            out.push((value.0.clone(), value.1.clone(), c));
        }
        out
    }
}

impl<A: Strategy, B: Strategy, C: Strategy, D: Strategy> Strategy for (A, B, C, D) {
    type Value = (A::Value, B::Value, C::Value, D::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (
            self.0.generate(rng),
            self.1.generate(rng),
            self.2.generate(rng),
            self.3.generate(rng),
        )
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone(), value.2.clone(), value.3.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b, value.2.clone(), value.3.clone()));
        }
        for c in self.2.shrink(&value.2) {
            out.push((value.0.clone(), value.1.clone(), c, value.3.clone()));
        }
        for d in self.3.shrink(&value.3) {
            out.push((value.0.clone(), value.1.clone(), value.2.clone(), d));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng;

    #[test]
    fn generation_is_deterministic_per_seed() {
        let s = vec_of(range_u(0, 100), 0, 10);
        let a = s.generate(&mut rng(42, 7));
        let b = s.generate(&mut rng(42, 7));
        assert_eq!(a, b);
        let c = s.generate(&mut rng(42, 8));
        // Different case salt gives an independent stream (astronomically
        // unlikely to collide on a 10-element draw — and deterministic,
        // so this cannot flake).
        assert!(a != c || a.is_empty());
    }

    #[test]
    fn range_u_shrinks_toward_lo_and_terminates() {
        let s = range_u(3, 1000);
        assert!(s.shrink(&3).is_empty());
        let cands = s.shrink(&900);
        assert_eq!(cands[0], 3);
        assert!(cands.iter().all(|&c| c >= 3 && c < 900));
        // Walk the greedy chain with an always-failing property: every
        // step strictly decreases, so it must reach the floor.
        let mut v = 900usize;
        let mut steps = 0;
        while let Some(&next) = s.shrink(&v).first() {
            assert!(next < v);
            v = next;
            steps += 1;
            assert!(steps < 2000);
        }
        assert_eq!(v, 3);
    }

    #[test]
    fn range_i64_offers_zero_when_span_crosses_it() {
        let s = range_i64(-2_000_000, 2_000_000);
        let cands = s.shrink(&1_500_000);
        assert!(cands.contains(&-2_000_000));
        assert!(cands.contains(&0));
    }

    #[test]
    fn choice_shrinks_to_earlier_options_only() {
        let s = choice(vec!["a", "b", "c"]);
        assert!(s.shrink(&"a").is_empty());
        assert_eq!(s.shrink(&"c"), vec!["a", "b"]);
    }

    #[test]
    fn vec_shrink_tries_min_prefix_first_then_single_removals() {
        let s = vec_of(range_u(0, 10), 0, 8);
        let v = vec![5usize, 6, 7, 8];
        let cands = s.shrink(&v);
        assert_eq!(cands[0], Vec::<usize>::new());
        assert!(cands.contains(&vec![6, 7, 8]));
        assert!(cands.contains(&vec![5, 6, 7]));
        // Element shrinks preserve length.
        assert!(cands.contains(&vec![0, 6, 7, 8]));
        // Length floor is respected.
        let s2 = vec_of(range_u(0, 10), 2, 8);
        assert!(s2.shrink(&vec![1, 2]).iter().all(|w| w.len() >= 2));
    }

    #[test]
    fn tuple_shrink_varies_one_component_at_a_time() {
        let s = (range_u(0, 10), range_u(0, 10));
        let cands = s.shrink(&(4, 7));
        assert!(cands.contains(&(0, 7)));
        assert!(cands.contains(&(4, 0)));
        assert!(!cands.contains(&(0, 0)));
    }
}
