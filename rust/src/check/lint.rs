//! Repo-invariant lint: a plain source scan over `rust/src/` enforcing
//! rules rustc/clippy cannot express. Zero-dependency, like the rest of
//! this module; run it as `harness lint` (CI runs it as a blocking
//! job).
//!
//! The four rules:
//!
//! 1. **trace-gating** — every `TraceEvent` construction must sit
//!    within 40 lines *after* an `enabled()` guard, so the flight
//!    recorder's zero-cost-when-off contract cannot silently regress.
//!    (`obs/` builds the events, `bin/` consumes finished traces, and
//!    `check/` holds this scanner — all exempt.)
//! 2. **wall-clock** — no `Instant::now` / `SystemTime` inside
//!    DES-path modules: simulated time comes from the event core, and
//!    a stray wall-clock read breaks per-seed bit-identity.
//! 3. **map-order** — no raw `HashMap`/`HashSet` in DES-path modules
//!    (use `util::FastMap`/`FastSet`): std's randomized iteration
//!    order feeding dispatch would destroy determinism.
//!    (`util/fastmap.rs`, which wraps the raw types, is exempt.)
//! 4. **no-escape-hatch** — the keyword the `lib.rs` `forbid` header
//!    bans stays banned everywhere under `rust/src/`, including build
//!    scripts and binaries the header does not cover.
//!
//! The scan strips `//` and `/* */` comments before matching, so
//! prose mentioning a banned name does not trip the rules. It does not
//! parse string literals; a banned token smuggled inside one is flagged
//! conservatively, which is the failure direction we want.

use std::fs;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the scanned source root (`/`-separated).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule id (`trace-gating`, `wall-clock`, `map-order`,
    /// `no-escape-hatch`).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

/// Result of scanning a source tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All violations, in path order.
    pub violations: Vec<Violation>,
}

impl LintReport {
    /// True when no rule fired.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Module prefixes that execute on the DES path — simulated time only,
/// deterministic iteration only. Everything the engines touch per
/// event lives under one of these.
const DES_PATHS: &[&str] = &[
    "apps/",
    "config/",
    "coordinator/des.rs",
    "coordinator/tl.rs",
    "coordinator/topology.rs",
    "dataflow/",
    "engine/",
    "metrics/",
    "roadnet/",
    "service/admission.rs",
    "service/engine.rs",
    "service/query.rs",
    "service/scheduler.rs",
    "sim/",
    "tuning/",
    "util/",
];

/// How far (in lines) a `TraceEvent` construction may sit after its
/// `enabled()` guard and still count as gated.
const GATE_WINDOW: usize = 40;

fn is_des_path(rel: &str) -> bool {
    DES_PATHS.iter().any(|p| rel.starts_with(p))
}

/// Remove `//` line comments and `/* */` block comments (block state
/// carries across lines). String literals are not parsed — see the
/// module docs for why that bias is acceptable.
fn strip_comments(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_block = false;
    for line in text.lines() {
        let cs: Vec<char> = line.chars().collect();
        let mut s = String::new();
        let mut i = 0;
        while i < cs.len() {
            if in_block {
                if cs[i] == '*' && i + 1 < cs.len() && cs[i + 1] == '/' {
                    in_block = false;
                    i += 2;
                } else {
                    i += 1;
                }
                continue;
            }
            if cs[i] == '/' && i + 1 < cs.len() {
                if cs[i + 1] == '/' {
                    break;
                }
                if cs[i + 1] == '*' {
                    in_block = true;
                    i += 2;
                    continue;
                }
            }
            s.push(cs[i]);
            i += 1;
        }
        out.push(s);
    }
    out
}

/// Does `line` contain the rule-4 keyword outside the one allowed
/// position (the `lib.rs` forbid attribute, where it is followed by
/// `_code`)?
fn has_forbidden_keyword(line: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = line[start..].find(needle) {
        let abs = start + pos;
        if !line[abs + needle.len()..].starts_with("_code") {
            return true;
        }
        start = abs + needle.len();
    }
    false
}

fn lint_file(rel: &str, text: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let stripped = strip_comments(text);

    // check/ holds the scanner itself (needle strings, fixtures);
    // rules 1-3 never apply to it. Rule 4 applies everywhere, so its
    // needle is assembled at runtime to keep this file clean.
    let in_check = rel.starts_with("check/");
    let rule1_applies = !in_check && !rel.starts_with("obs/") && !rel.starts_with("bin/");
    let des = !in_check && is_des_path(rel);
    let rule3_exempt = rel == "util/fastmap.rs";
    let rule4_needle: String = ["uns", "afe"].concat();

    let mut last_enabled: Option<usize> = None;
    for (i, line) in stripped.iter().enumerate() {
        let lineno = i + 1;
        if line.contains("enabled()") {
            last_enabled = Some(i);
        }
        if rule1_applies && line.contains("TraceEvent::") {
            let gated = matches!(last_enabled, Some(j) if i - j <= GATE_WINDOW);
            if !gated {
                out.push(Violation {
                    file: rel.to_string(),
                    line: lineno,
                    rule: "trace-gating",
                    msg: format!(
                        "TraceEvent construction with no enabled() guard in the \
                         preceding {GATE_WINDOW} lines"
                    ),
                });
            }
        }
        if des && (line.contains("Instant::now") || line.contains("SystemTime")) {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "wall-clock",
                msg: "wall-clock read in a DES-path module; simulated time must come \
                      from the event core"
                    .to_string(),
            });
        }
        if des && !rule3_exempt && (line.contains("HashMap") || line.contains("HashSet")) {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "map-order",
                msg: "raw std map/set in a DES-path module; use util::FastMap / \
                      util::FastSet for deterministic iteration"
                    .to_string(),
            });
        }
        if has_forbidden_keyword(line, &rule4_needle) {
            out.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: "no-escape-hatch",
                msg: format!("`{rule4_needle}` is forbidden repo-wide"),
            });
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Scan every `.rs` file under `src_root`, applying path-scoped rules
/// relative to that root. Files are visited in sorted path order so
/// reports are stable.
pub fn lint_tree(src_root: &Path) -> LintReport {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files);
    files.sort();
    let mut report = LintReport::default();
    for f in files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(text) = fs::read_to_string(&f) else {
            continue;
        };
        report.files_scanned += 1;
        report.violations.extend(lint_file(&rel, &text));
    }
    report
}

/// Scan this repository's own `rust/src/` tree (located via the
/// compile-time manifest dir, so it works from any cwd in a checkout).
pub fn lint_repo() -> LintReport {
    lint_tree(&Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a throwaway fixture tree under `target/` (inside the
    /// repo, gitignored) and return its root.
    fn fixture_root(tag: &str) -> PathBuf {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("target")
            .join("lint_fixtures")
            .join(format!("{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        root
    }

    fn write(root: &Path, rel: &str, content: &str) {
        let p = root.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(p, content).unwrap();
    }

    #[test]
    fn doctored_fixture_trips_every_rule_and_clean_tree_passes() {
        let root = fixture_root("doctored");
        write(
            &root,
            "engine/clean.rs",
            "pub fn ok() -> u32 { 1 }\n// Instant::now in a comment is fine\n",
        );
        write(
            &root,
            "engine/bad_time.rs",
            "pub fn t() -> u128 { std::time::Instant::now().elapsed().as_nanos() }\n",
        );
        write(
            &root,
            "engine/bad_map.rs",
            "use std::collections::HashMap;\npub type M = HashMap<u32, u32>;\n",
        );
        write(
            &root,
            "apps/bad_trace.rs",
            "pub fn emit(obs: &mut Vec<String>) {\n    obs.push(format!(\"{:?}\", TraceEvent::Generated));\n}\n",
        );
        let esc = ["uns", "afe"].concat();
        write(
            &root,
            "sim/bad_escape.rs",
            &format!("pub fn f() {{ {esc} {{ }} }}\n"),
        );
        // Wall-clock outside the DES paths is allowed.
        write(
            &root,
            "obs/ok_time.rs",
            "pub fn now() -> std::time::Instant { std::time::Instant::now() }\n",
        );
        // The forbid attribute's own spelling is allowed.
        write(&root, "lib.rs", &format!("#![forbid({esc}_code)]\n"));

        let report = lint_tree(&root);
        assert_eq!(report.files_scanned, 7);
        let fired: Vec<(&str, &str)> = report
            .violations
            .iter()
            .map(|v| (v.file.as_str(), v.rule))
            .collect();
        assert!(fired.contains(&("engine/bad_time.rs", "wall-clock")), "{fired:?}");
        assert!(fired.contains(&("engine/bad_map.rs", "map-order")), "{fired:?}");
        assert!(fired.contains(&("apps/bad_trace.rs", "trace-gating")), "{fired:?}");
        assert!(
            fired.contains(&("sim/bad_escape.rs", "no-escape-hatch")),
            "{fired:?}"
        );
        assert!(
            !fired.iter().any(|(f, _)| *f == "engine/clean.rs"
                || *f == "obs/ok_time.rs"
                || *f == "lib.rs"),
            "{fired:?}"
        );
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn sharded_execution_files_are_on_the_des_path() {
        // The sharded merge loop and the roadnet partition run per
        // event; both must stay under the wall-clock and map-order
        // bans. The directory prefixes cover them — this pins that
        // coverage so a future path reshuffle cannot silently drop it.
        assert!(is_des_path("engine/sharded.rs"));
        assert!(is_des_path("engine/core.rs"));
        assert!(is_des_path("roadnet/partition.rs"));
        assert!(is_des_path("service/engine.rs"));
        assert!(!is_des_path("obs/jsonl.rs"));
        assert!(!is_des_path("bin/harness.rs"));
    }

    #[test]
    fn enabled_gate_within_window_passes_and_outside_window_fails() {
        let root = fixture_root("window");
        let gated = "pub fn f(on: bool) {\n    if obs.enabled() {\n        emit(TraceEvent::Generated);\n    }\n}\n";
        write(&root, "tuning/gated.rs", gated);
        let mut far = String::from("pub fn g() {\n    if obs.enabled() { }\n");
        for _ in 0..GATE_WINDOW + 1 {
            far.push_str("    let _ = 0;\n");
        }
        far.push_str("    emit(TraceEvent::Generated);\n}\n");
        write(&root, "tuning/far.rs", &far);

        let report = lint_tree(&root);
        let files: Vec<&str> = report.violations.iter().map(|v| v.file.as_str()).collect();
        assert!(!files.contains(&"tuning/gated.rs"), "{files:?}");
        assert!(files.contains(&"tuning/far.rs"), "{files:?}");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn block_comments_are_stripped_across_lines() {
        let stripped = strip_comments("a /* x\ny */ b\nc");
        assert_eq!(stripped, vec!["a ".to_string(), " b".to_string(), "c".to_string()]);
    }

    #[test]
    fn forbidden_keyword_allows_only_the_attribute_spelling() {
        let needle = ["uns", "afe"].concat();
        assert!(!has_forbidden_keyword(
            &format!("#![forbid({needle}_code)]"),
            &needle
        ));
        assert!(has_forbidden_keyword(&format!("{needle} fn f()"), &needle));
        assert!(has_forbidden_keyword(
            &format!("#![forbid({needle}_code)] {needle} {{}}"),
            &needle
        ));
        assert!(!has_forbidden_keyword("nothing here", &needle));
    }
}
