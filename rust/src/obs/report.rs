//! One reporting function for every path.
//!
//! `harness mq`, `harness compute` and the live `TrackingService`
//! previously each hand-rolled their own summary printer. All three now
//! build [`ReportRow`]s — from a [`MetricsSnapshot`], per-query
//! counters, or an end-of-run `Summary` — and render through
//! [`render_rows`], so the columns (and the percentages in them) can
//! never drift apart between the live and DES paths.

use std::fmt::Write as _;

use crate::metrics::Summary;
use crate::obs::{MetricsSnapshot, QueryCounters};

/// One row of the shared delivery report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReportRow {
    pub label: String,
    pub generated: u64,
    pub on_time: u64,
    pub delayed: u64,
    pub dropped: u64,
    /// Latency columns are optional: mid-run metrics snapshots don't
    /// carry percentile state, end-of-run summaries do.
    pub median_latency_s: Option<f64>,
    pub p99_latency_s: Option<f64>,
    /// Free-form trailing cell (status, peak cams, fusion count, ...).
    pub extra: String,
}

impl ReportRow {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), ..Self::default() }
    }

    /// Row from a registry snapshot (mid-run or end-of-run; any path).
    pub fn from_snapshot(
        label: impl Into<String>,
        s: &MetricsSnapshot,
    ) -> Self {
        Self {
            label: label.into(),
            generated: s.generated,
            on_time: s.on_time,
            delayed: s.delayed,
            dropped: s.dropped_total(),
            ..Self::default()
        }
    }

    /// Row from one query's counters in a snapshot.
    pub fn from_query(
        label: impl Into<String>,
        c: &QueryCounters,
    ) -> Self {
        Self {
            label: label.into(),
            generated: c.generated,
            on_time: c.on_time,
            delayed: c.delayed,
            dropped: c.dropped,
            ..Self::default()
        }
    }

    /// Row from an end-of-run ledger summary (has latency percentiles).
    pub fn from_summary(label: impl Into<String>, s: &Summary) -> Self {
        Self {
            label: label.into(),
            generated: s.generated,
            on_time: s.on_time,
            delayed: s.delayed,
            dropped: s.dropped,
            median_latency_s: Some(s.latency.median),
            p99_latency_s: Some(s.latency.p99),
            extra: String::new(),
        }
    }

    pub fn with_extra(mut self, extra: impl Into<String>) -> Self {
        self.extra = extra.into();
        self
    }

    pub fn delay_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.delayed as f64 / self.generated as f64
        }
    }

    pub fn drop_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.dropped as f64 / self.generated as f64
        }
    }
}

/// Render rows as the shared aligned table (header included). Latency
/// columns print `-` when a row has no percentile state.
pub fn render_rows(rows: &[ReportRow]) -> String {
    let label_w = rows
        .iter()
        .map(|r| r.label.len())
        .max()
        .unwrap_or(0)
        .max("query".len());
    let mut out = String::new();
    let _ = writeln!(
        out,
        "  {:<label_w$} {:>8} {:>8} {:>8}({:>5}) {:>8}({:>5}) {:>8} {:>8}  {}",
        "query",
        "gen",
        "on-time",
        "delayed",
        "%",
        "dropped",
        "%",
        "median-s",
        "p99-s",
        "notes"
    );
    for r in rows {
        let med = r
            .median_latency_s
            .map(|v| format!("{v:.2}"))
            .unwrap_or_else(|| "-".into());
        let p99 = r
            .p99_latency_s
            .map(|v| format!("{v:.2}"))
            .unwrap_or_else(|| "-".into());
        let _ = writeln!(
            out,
            "  {:<label_w$} {:>8} {:>8} {:>8}({:>4.1}%) {:>8}({:>4.1}%) {:>8} {:>8}  {}",
            r.label,
            r.generated,
            r.on_time,
            r.delayed,
            100.0 * r.delay_rate(),
            r.dropped,
            100.0 * r.drop_rate(),
            med,
            p99,
            r.extra
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_render_aligned_with_optional_latency() {
        let rows = vec![
            ReportRow {
                label: "q0-app1".into(),
                generated: 100,
                on_time: 90,
                delayed: 5,
                dropped: 5,
                median_latency_s: Some(1.25),
                p99_latency_s: Some(9.5),
                extra: "active".into(),
            },
            ReportRow::new("mid-run").with_extra("snapshot"),
        ];
        let t = render_rows(&rows);
        assert!(t.contains("q0-app1"));
        assert!(t.contains("1.25"));
        assert!(t.contains("snapshot"));
        // No-latency row prints dashes, not zeros.
        let mid = t.lines().find(|l| l.contains("mid-run")).unwrap();
        assert!(mid.contains('-'));
        assert!(t.lines().count() == 3); // header + 2 rows
    }

    #[test]
    fn rates_guard_division_by_zero() {
        let r = ReportRow::new("empty");
        assert_eq!(r.delay_rate(), 0.0);
        assert_eq!(r.drop_rate(), 0.0);
    }
}
