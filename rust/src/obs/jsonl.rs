//! Schema-versioned JSONL trace export and its validator.
//!
//! The first line of a trace is a header object carrying
//! [`TRACE_SCHEMA`]; every following line is one [`TraceEvent`]
//! serialized via [`TraceEvent::to_json`]. [`validate_trace`] is the
//! inverse contract: it re-parses a trace with the hand-rolled codec,
//! checks the schema version and the per-kind required fields, and
//! returns the counts (`TraceCheck`) that `harness trace`, CI and the
//! property tests reconcile against `Ledger`/`QueryLedgers`.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::obs::{Gate, ObsSink, Scope, SpanStats, TraceEvent, TRACE_SCHEMA};
use crate::util::json::obj;
use crate::util::{Json, Micros};

enum Out {
    File(BufWriter<File>),
    Mem(Vec<u8>),
}

struct Inner {
    out: Out,
    /// Event lines written (excludes the header).
    lines: u64,
}

impl Inner {
    fn write_line(&mut self, j: &Json) {
        let line = j.to_string();
        match &mut self.out {
            Out::File(w) => {
                let _ = writeln!(w, "{line}");
            }
            Out::Mem(v) => {
                v.extend_from_slice(line.as_bytes());
                v.push(b'\n');
            }
        }
    }
}

/// JSONL trace writer. Cheap to clone (shared `Arc` innards); the
/// in-memory variant backs the property tests and `--smoke` runs, the
/// file variant backs `harness trace`.
#[derive(Clone)]
pub struct JsonlSink {
    inner: Arc<Mutex<Inner>>,
    spans: Arc<SpanStats>,
}

impl JsonlSink {
    fn with_out(out: Out) -> Self {
        let mut inner = Inner { out, lines: 0 };
        inner.write_line(&obj([("schema", TRACE_SCHEMA.into())]));
        Self {
            inner: Arc::new(Mutex::new(inner)),
            spans: Arc::new(SpanStats::default()),
        }
    }

    /// Open a trace file, writing the schema header line.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        let f = File::create(path)?;
        Ok(Self::with_out(Out::File(BufWriter::new(f))))
    }

    /// An in-memory trace (read back with [`JsonlSink::contents`]).
    pub fn in_memory() -> Self {
        Self::with_out(Out::Mem(Vec::new()))
    }

    /// Event lines written so far (excluding the header).
    pub fn lines(&self) -> u64 {
        self.inner.lock().unwrap().lines
    }

    /// The buffered trace text (in-memory sinks only; `None` for file
    /// sinks — read the file instead).
    pub fn contents(&self) -> Option<String> {
        let inner = self.inner.lock().unwrap();
        match &inner.out {
            Out::Mem(v) => {
                Some(String::from_utf8_lossy(v).into_owned())
            }
            Out::File(_) => None,
        }
    }

    /// Flush buffered output (file sinks).
    pub fn flush(&self) {
        if let Out::File(w) = &mut self.inner.lock().unwrap().out {
            let _ = w.flush();
        }
    }

    /// The profiling span accumulators (shared with clones).
    pub fn spans(&self) -> &SpanStats {
        &self.spans
    }
}

impl ObsSink for JsonlSink {
    #[inline]
    fn enabled(&self) -> bool {
        true
    }

    fn emit(&self, t: Micros, ev: &TraceEvent) {
        let j = ev.to_json(t);
        let mut inner = self.inner.lock().unwrap();
        inner.write_line(&j);
        inner.lines += 1;
    }

    fn profiled(&self) -> bool {
        true
    }

    fn record_span(&self, scope: Scope, ns: u64) {
        self.spans.record(scope, ns);
    }
}

/// Validation result: schema-checked counts for reconciliation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceCheck {
    /// Event lines (header excluded).
    pub lines: u64,
    pub generated: u64,
    pub completed: u64,
    pub on_time: u64,
    pub detections: u64,
    /// Indexed by `Gate::id()`.
    pub drops_gate: [u64; 4],
    pub exempted: u64,
    pub batches_executed: u64,
    /// Events consumed by injected faults — the third terminal class.
    pub lost_to_fault: u64,
    /// Recovery retries observed (`fault_retry` lines).
    pub fault_retries: u64,
    /// Cross-shard handoff envelopes (`cross_shard` lines).
    pub cross_shard: u64,
    /// Adaptation commands applied (`adaptation` lines).
    pub adaptations: u64,
    /// Line count per `ev` kind.
    pub kinds: BTreeMap<String, u64>,
    /// `(query, event) -> (generated count, terminal count)` where a
    /// terminal is a completion, a drop, or a fault loss. Conservation
    /// holds when every generated pair has exactly one terminal and no
    /// terminal lacks a generation.
    pub per_event: BTreeMap<(u32, u64), (u32, u32)>,
}

impl TraceCheck {
    pub fn dropped_total(&self) -> u64 {
        self.drops_gate.iter().sum()
    }

    /// Generated events with no terminal yet (in flight at trace end —
    /// legitimate for truncated/live traces, zero for full DES runs
    /// whose ledgers conserve).
    pub fn unterminated(&self) -> u64 {
        self.per_event
            .values()
            .filter(|&&(g, t)| g > 0 && t == 0)
            .count() as u64
    }

    /// Conservation violations: events terminated more than once, or
    /// terminated without ever being generated. Empty on a sound
    /// trace.
    pub fn violations(&self) -> Vec<((u32, u64), (u32, u32))> {
        self.per_event
            .iter()
            .filter(|(_, &(g, t))| t > g.max(1) || (g == 0 && t > 0))
            .map(|(&k, &v)| (k, v))
            .collect()
    }
}

fn num(j: &Json, key: &str) -> Result<f64, String> {
    j.at(key)
        .as_f64()
        .ok_or_else(|| format!("missing/non-numeric field `{key}`"))
}

fn st(j: &Json, key: &str) -> Result<String, String> {
    Ok(j.at(key)
        .as_str()
        .ok_or_else(|| format!("missing/non-string field `{key}`"))?
        .to_string())
}

fn boolean(j: &Json, key: &str) -> Result<bool, String> {
    j.at(key)
        .as_bool()
        .ok_or_else(|| format!("missing/non-bool field `{key}`"))
}

const STAGES: [&str; 6] = ["fc", "va", "cr", "tl", "qf", "uv"];

fn stage_field(j: &Json) -> Result<(), String> {
    let s = st(j, "stage")?;
    if STAGES.contains(&s.as_str()) {
        Ok(())
    } else {
        Err(format!("unknown stage `{s}`"))
    }
}

/// Validate a JSONL trace: header schema, per-line JSON
/// well-formedness, per-kind required fields. Returns the reconciled
/// counts or a message naming the first offending line.
pub fn validate_trace(text: &str) -> Result<TraceCheck, String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines
        .next()
        .ok_or_else(|| "empty trace: no header line".to_string())?;
    let h = Json::parse(header)
        .map_err(|e| format!("line 1: bad header JSON: {e}"))?;
    match h.at("schema").as_str() {
        Some(s) if s == TRACE_SCHEMA => {}
        Some(s) => {
            return Err(format!(
                "schema mismatch: got `{s}`, want `{TRACE_SCHEMA}`"
            ))
        }
        None => return Err("header missing `schema` field".into()),
    }

    let mut c = TraceCheck::default();
    for (i, line) in lines {
        let lineno = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| format!("line {lineno}: bad JSON: {e}"))?;
        let err = |e: String| format!("line {lineno}: {e}");
        num(&j, "t_us").map_err(err)?;
        let kind = st(&j, "ev").map_err(|e| format!("line {lineno}: {e}"))?;
        let err = |e: String| format!("line {lineno}: [{kind}] {e}");
        c.lines += 1;
        *c.kinds.entry(kind.clone()).or_insert(0) += 1;
        match kind.as_str() {
            "generated" => {
                let ev = num(&j, "event").map_err(err)? as u64;
                let q = num(&j, "query").map_err(err)? as u32;
                num(&j, "camera").map_err(err)?;
                c.generated += 1;
                c.per_event.entry((q, ev)).or_insert((0, 0)).0 += 1;
            }
            "drop" => {
                let gate = num(&j, "gate").map_err(err)? as u8;
                Gate::from_id(gate).ok_or_else(|| {
                    err(format!("bad gate id {gate}"))
                })?;
                stage_field(&j).map_err(err)?;
                let ev = num(&j, "event").map_err(err)? as u64;
                let q = num(&j, "query").map_err(err)? as u32;
                num(&j, "batch").map_err(err)?;
                num(&j, "eps_us").map_err(err)?;
                num(&j, "xi_us").map_err(err)?;
                c.drops_gate[gate as usize] += 1;
                c.per_event.entry((q, ev)).or_insert((0, 0)).1 += 1;
            }
            "exempted" => {
                let gate = num(&j, "gate").map_err(err)? as u8;
                Gate::from_id(gate).ok_or_else(|| {
                    err(format!("bad gate id {gate}"))
                })?;
                stage_field(&j).map_err(err)?;
                num(&j, "event").map_err(err)?;
                num(&j, "query").map_err(err)?;
                c.exempted += 1;
            }
            "batch_formed" => {
                stage_field(&j).map_err(err)?;
                num(&j, "task").map_err(err)?;
                num(&j, "size").map_err(err)?;
            }
            "batch_executed" => {
                stage_field(&j).map_err(err)?;
                num(&j, "task").map_err(err)?;
                num(&j, "size").map_err(err)?;
                num(&j, "est_us").map_err(err)?;
                num(&j, "actual_us").map_err(err)?;
                c.batches_executed += 1;
            }
            "xi_observed" => {
                stage_field(&j).map_err(err)?;
                num(&j, "task").map_err(err)?;
                num(&j, "b_eff").map_err(err)?;
                num(&j, "actual_us").map_err(err)?;
                num(&j, "alpha_us").map_err(err)?;
                num(&j, "beta_us").map_err(err)?;
            }
            "nob_retune" => {
                stage_field(&j).map_err(err)?;
                num(&j, "task").map_err(err)?;
            }
            "refinement" => {
                num(&j, "query").map_err(err)?;
                num(&j, "seq").map_err(err)?;
            }
            "query" => {
                num(&j, "query").map_err(err)?;
                st(&j, "phase").map_err(err)?;
            }
            "spotlight" => {
                num(&j, "query").map_err(err)?;
                num(&j, "active").map_err(err)?;
            }
            "compute_factor" => {
                num(&j, "node").map_err(err)?;
                num(&j, "factor").map_err(err)?;
            }
            "bandwidth" => {
                num(&j, "bps").map_err(err)?;
            }
            "completed" => {
                let ev = num(&j, "event").map_err(err)? as u64;
                let q = num(&j, "query").map_err(err)? as u32;
                num(&j, "latency_us").map_err(err)?;
                let on_time = boolean(&j, "on_time").map_err(err)?;
                let detected = boolean(&j, "detected").map_err(err)?;
                c.completed += 1;
                if on_time {
                    c.on_time += 1;
                }
                if detected {
                    c.detections += 1;
                }
                c.per_event.entry((q, ev)).or_insert((0, 0)).1 += 1;
            }
            "node_fault" => {
                num(&j, "node").map_err(err)?;
                boolean(&j, "up").map_err(err)?;
            }
            "camera_fault" => {
                num(&j, "camera").map_err(err)?;
                boolean(&j, "up").map_err(err)?;
            }
            "lost_to_fault" => {
                let ev = num(&j, "event").map_err(err)? as u64;
                let q = num(&j, "query").map_err(err)? as u32;
                stage_field(&j).map_err(err)?;
                c.lost_to_fault += 1;
                c.per_event.entry((q, ev)).or_insert((0, 0)).1 += 1;
            }
            "fault_retry" => {
                num(&j, "event").map_err(err)?;
                num(&j, "query").map_err(err)?;
                num(&j, "attempt").map_err(err)?;
                c.fault_retries += 1;
            }
            "redispatch" => {
                stage_field(&j).map_err(err)?;
                num(&j, "from_task").map_err(err)?;
                num(&j, "to_task").map_err(err)?;
                num(&j, "events").map_err(err)?;
            }
            "cross_shard" => {
                num(&j, "from_shard").map_err(err)?;
                num(&j, "to_shard").map_err(err)?;
                num(&j, "seq").map_err(err)?;
                c.cross_shard += 1;
            }
            "adaptation" => {
                num(&j, "camera").map_err(err)?;
                num(&j, "seq").map_err(err)?;
                num(&j, "level").map_err(err)?;
                st(&j, "variant").map_err(err)?;
                c.adaptations += 1;
            }
            other => {
                return Err(format!(
                    "line {lineno}: unknown event kind `{other}`"
                ))
            }
        }
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Stage;

    #[test]
    fn in_memory_trace_round_trips() {
        let s = JsonlSink::in_memory();
        s.emit(
            10,
            &TraceEvent::Generated { event: 1, query: 0, camera: 3 },
        );
        s.emit(
            999,
            &TraceEvent::Completed {
                event: 1,
                query: 0,
                latency_us: 989,
                on_time: true,
                detected: false,
            },
        );
        assert_eq!(s.lines(), 2);
        let text = s.contents().unwrap();
        let check = validate_trace(&text).unwrap();
        assert_eq!(check.lines, 2);
        assert_eq!(check.generated, 1);
        assert_eq!(check.completed, 1);
        assert_eq!(check.on_time, 1);
        assert_eq!(check.unterminated(), 0);
        assert!(check.violations().is_empty());
    }

    #[test]
    fn drop_and_conservation_accounting() {
        let s = JsonlSink::in_memory();
        for ev in 0..3u64 {
            s.emit(
                0,
                &TraceEvent::Generated { event: ev, query: 2, camera: 0 },
            );
        }
        s.emit(
            5,
            &TraceEvent::Drop {
                gate: Gate::Exec,
                stage: Stage::Cr,
                event: 0,
                query: 2,
                batch: 4,
                eps_us: 6_000,
                xi_us: 18_000,
            },
        );
        s.emit(
            6,
            &TraceEvent::Completed {
                event: 1,
                query: 2,
                latency_us: 6,
                on_time: true,
                detected: true,
            },
        );
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.generated, 3);
        assert_eq!(check.drops_gate[Gate::Exec.id() as usize], 1);
        assert_eq!(check.dropped_total(), 1);
        assert_eq!(check.detections, 1);
        assert_eq!(check.unterminated(), 1); // event 2 in flight
        assert!(check.violations().is_empty());
    }

    #[test]
    fn lost_to_fault_is_a_terminal() {
        let s = JsonlSink::in_memory();
        for ev in 0..2u64 {
            s.emit(
                0,
                &TraceEvent::Generated { event: ev, query: 1, camera: 0 },
            );
        }
        s.emit(1, &TraceEvent::NodeFault { node: 2, up: false });
        s.emit(
            2,
            &TraceEvent::FaultRetry { event: 0, query: 1, attempt: 0 },
        );
        s.emit(
            3,
            &TraceEvent::LostToFault {
                event: 0,
                query: 1,
                stage: Stage::Va,
            },
        );
        s.emit(
            4,
            &TraceEvent::Redispatch {
                stage: Stage::Va,
                from_task: 3,
                to_task: 4,
                events: 1,
            },
        );
        s.emit(5, &TraceEvent::CameraFault { camera: 7, up: true });
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.lost_to_fault, 1);
        assert_eq!(check.fault_retries, 1);
        assert_eq!(check.unterminated(), 1); // event 1 in flight
        assert!(check.violations().is_empty());
        // A lost event cannot also complete: that's a violation.
        s.emit(
            6,
            &TraceEvent::Completed {
                event: 0,
                query: 1,
                latency_us: 6,
                on_time: true,
                detected: false,
            },
        );
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.violations(), vec![((1, 0), (1, 2))]);
    }

    #[test]
    fn cross_shard_is_counted_not_terminal() {
        let s = JsonlSink::in_memory();
        s.emit(
            0,
            &TraceEvent::Generated { event: 3, query: 0, camera: 1 },
        );
        s.emit(
            2,
            &TraceEvent::CrossShard { from_shard: 0, to_shard: 2, seq: 41 },
        );
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.cross_shard, 1);
        assert_eq!(check.kinds["cross_shard"], 1);
        // A handoff is transport, not a terminal: the event stays in
        // flight and conservation is untouched.
        assert_eq!(check.unterminated(), 1);
        assert!(check.violations().is_empty());
        // Malformed handoff lines are rejected.
        let missing = format!(
            "{{\"schema\":\"{TRACE_SCHEMA}\"}}\n{{\"t_us\":1,\"ev\":\"cross_shard\",\"from_shard\":0}}\n"
        );
        let e = validate_trace(&missing).unwrap_err();
        assert!(e.contains("to_shard"), "{e}");
    }

    #[test]
    fn adaptation_is_counted_not_terminal() {
        let s = JsonlSink::in_memory();
        s.emit(
            0,
            &TraceEvent::Generated { event: 5, query: 0, camera: 2 },
        );
        s.emit(
            3,
            &TraceEvent::Adaptation {
                camera: 2,
                seq: 1,
                level: 2,
                variant: "cr_small",
            },
        );
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.adaptations, 1);
        assert_eq!(check.kinds["adaptation"], 1);
        // A command is control plane, not a terminal: the data event
        // stays in flight and conservation is untouched.
        assert_eq!(check.unterminated(), 1);
        assert!(check.violations().is_empty());
        // Malformed adaptation lines are rejected.
        let missing = format!(
            "{{\"schema\":\"{TRACE_SCHEMA}\"}}\n{{\"t_us\":1,\"ev\":\"adaptation\",\"camera\":2,\"seq\":1,\"level\":0}}\n"
        );
        let e = validate_trace(&missing).unwrap_err();
        assert!(e.contains("variant"), "{e}");
    }

    #[test]
    fn schema_mismatch_and_bad_lines_rejected() {
        assert!(validate_trace("").is_err());
        assert!(validate_trace("{\"schema\":\"bogus-v9\"}\n").is_err());
        let bad_kind =
            format!("{{\"schema\":\"{TRACE_SCHEMA}\"}}\n{{\"t_us\":1,\"ev\":\"nope\"}}\n");
        assert!(validate_trace(&bad_kind).unwrap_err().contains("nope"));
        let missing_field = format!(
            "{{\"schema\":\"{TRACE_SCHEMA}\"}}\n{{\"t_us\":1,\"ev\":\"generated\",\"event\":4}}\n"
        );
        let e = validate_trace(&missing_field).unwrap_err();
        assert!(e.contains("query"), "{e}");
    }

    #[test]
    fn double_termination_is_a_violation() {
        let s = JsonlSink::in_memory();
        s.emit(
            0,
            &TraceEvent::Generated { event: 9, query: 0, camera: 0 },
        );
        for _ in 0..2 {
            s.emit(
                1,
                &TraceEvent::Completed {
                    event: 9,
                    query: 0,
                    latency_us: 1,
                    on_time: true,
                    detected: false,
                },
            );
        }
        let check = validate_trace(&s.contents().unwrap()).unwrap();
        assert_eq!(check.violations(), vec![((0, 9), (1, 2))]);
    }
}
