//! The metrics registry: plain-atomics counters, gauges and
//! fixed-bucket histograms behind a cheaply clonable handle.
//!
//! Both DES engines and both live paths update the same registry
//! surface, so one snapshot schema covers all four execution paths:
//! per-stage batch-size and queue-delay histograms, per-gate drop
//! counters, the active-camera/active-query gauges, per-app ξ gauges,
//! and per-query in-time completion counters. The handle is `Arc`
//! innards — clone it out of an engine before `run(self)` consumes the
//! engine, or share it across live worker threads.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::dataflow::{QueryId, Stage};
use crate::obs::Gate;
use crate::util::json::obj;
use crate::util::{Json, Micros, MS, SEC};

/// Batch-size histogram bucket upper bounds (inclusive); one overflow
/// bucket follows.
pub const BATCH_BOUNDS: [usize; 8] = [1, 2, 4, 8, 12, 16, 20, 25];

/// Queue-delay histogram bucket upper bounds in µs (inclusive); one
/// overflow bucket follows.
pub const DELAY_BOUNDS_US: [Micros; 8] = [
    MS,
    10 * MS,
    100 * MS,
    500 * MS,
    SEC,
    5 * SEC,
    10 * SEC,
    15 * SEC,
];

/// Number of per-app slots (matches `AppKind::index()`).
const APPS: usize = 4;
/// Stages with executor metrics: 0 = VA, 1 = CR.
const EXEC_STAGES: usize = 2;

fn stage_slot(stage: Stage) -> Option<usize> {
    match stage {
        Stage::Va => Some(0),
        Stage::Cr => Some(1),
        _ => None,
    }
}

#[derive(Default)]
struct AtomicHist<const N: usize> {
    counts: [AtomicU64; N],
    overflow: AtomicU64,
}

impl<const N: usize> AtomicHist<N> {
    fn observe_at(&self, idx: Option<usize>) {
        match idx {
            Some(i) => self.counts[i].fetch_add(1, Relaxed),
            None => self.overflow.fetch_add(1, Relaxed),
        };
    }

    fn snapshot(&self) -> Vec<u64> {
        let mut v: Vec<u64> =
            self.counts.iter().map(|c| c.load(Relaxed)).collect();
        v.push(self.overflow.load(Relaxed));
        v
    }
}

#[derive(Default)]
struct Inner {
    generated: AtomicU64,
    on_time: AtomicU64,
    delayed: AtomicU64,
    detections: AtomicU64,
    drops_gate: [AtomicU64; 4], // indexed by Gate::id()
    batches: [AtomicU64; EXEC_STAGES],
    batch_events: [AtomicU64; EXEC_STAGES],
    batch_hist: [AtomicHist<{ BATCH_BOUNDS.len() }>; EXEC_STAGES],
    delay_hist: [AtomicHist<{ DELAY_BOUNDS_US.len() }>; EXEC_STAGES],
    xi_observations: AtomicU64,
    nob_retunes: AtomicU64,
    refinements: AtomicU64,
    // Fault / recovery counters (all zero on failure-free runs).
    faults_injected: AtomicU64,
    lost_to_fault: AtomicU64,
    fault_retries: AtomicU64,
    redispatched: AtomicU64,
    node_restarts: AtomicU64,
    worker_restarts: AtomicU64,
    /// Cross-shard handoff envelopes issued by the sharded DES (0 at
    /// K=1).
    cross_shard_msgs: AtomicU64,
    // Adaptation-plane counters (all zero with the identity ladder).
    adapt_minted: AtomicU64,
    adapt_applied: AtomicU64,
    adapt_stale: AtomicU64,
    active_cameras: AtomicI64,
    active_queries: AtomicI64,
    nodes_down: AtomicI64,
    /// Shard count of the engine publishing to this registry.
    shards: AtomicI64,
    /// Cameras currently below their native resolution rung.
    cameras_downshifted: AtomicI64,
    /// ξ(1) in µs per (app, stage) — the per-app pricing gauges; 0
    /// means "never priced".
    xi_app_us: [[AtomicI64; EXEC_STAGES]; APPS],
    per_query: Mutex<Vec<(QueryId, QueryCounters)>>,
    seconds: Mutex<Vec<SecondRow>>,
}

/// Per-query in-time completion counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryCounters {
    pub generated: u64,
    pub on_time: u64,
    pub delayed: u64,
    pub dropped: u64,
    pub lost_to_fault: u64,
}

/// One per-simulated-second cumulative row (dumped by the DES engines
/// alongside the `Timeline`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SecondRow {
    pub sec: i64,
    pub generated: u64,
    pub on_time: u64,
    pub delayed: u64,
    pub dropped: u64,
    pub batches_va: u64,
    pub batches_cr: u64,
    pub active_cameras: i64,
}

/// Cheap clonable handle over the shared atomics.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsRegistry")
            .field("snapshot", &self.snapshot())
            .finish()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- counters --------------------------------------------------------

    pub fn generated(&self) {
        self.inner.generated.fetch_add(1, Relaxed);
    }

    pub fn completed(&self, on_time: bool) {
        if on_time {
            self.inner.on_time.fetch_add(1, Relaxed);
        } else {
            self.inner.delayed.fetch_add(1, Relaxed);
        }
    }

    pub fn detection(&self) {
        self.inner.detections.fetch_add(1, Relaxed);
    }

    pub fn dropped(&self, gate: Gate) {
        self.inner.drops_gate[gate.id() as usize].fetch_add(1, Relaxed);
    }

    /// A batch of `size` events executed at `stage` with mean queue
    /// delay `mean_queue_us` — feeds the count, the batch-size
    /// histogram and the queue-delay histogram.
    pub fn batch_executed(
        &self,
        stage: Stage,
        size: usize,
        mean_queue_us: Micros,
    ) {
        let Some(s) = stage_slot(stage) else { return };
        self.inner.batches[s].fetch_add(1, Relaxed);
        self.inner.batch_events[s].fetch_add(size as u64, Relaxed);
        self.inner.batch_hist[s].observe_at(
            BATCH_BOUNDS.iter().position(|&b| size <= b),
        );
        self.inner.delay_hist[s].observe_at(
            DELAY_BOUNDS_US.iter().position(|&b| mean_queue_us <= b),
        );
    }

    pub fn xi_observed(&self) {
        self.inner.xi_observations.fetch_add(1, Relaxed);
    }

    pub fn nob_retune(&self) {
        self.inner.nob_retunes.fetch_add(1, Relaxed);
    }

    pub fn refinement(&self) {
        self.inner.refinements.fetch_add(1, Relaxed);
    }

    // ---- faults / recovery -----------------------------------------------

    /// A scheduled fault transition fired (node/camera/link/loss edge).
    pub fn fault_injected(&self) {
        self.inner.faults_injected.fetch_add(1, Relaxed);
    }

    /// An event was consumed by a fault (the `lost_to_fault` terminal).
    pub fn lost_to_fault(&self) {
        self.inner.lost_to_fault.fetch_add(1, Relaxed);
    }

    /// Recovery retried a fault-hit event/batch member.
    pub fn fault_retry(&self) {
        self.inner.fault_retries.fetch_add(1, Relaxed);
    }

    /// Recovery re-dispatched `n` orphaned events to a survivor.
    pub fn redispatched(&self, n: u64) {
        self.inner.redispatched.fetch_add(n, Relaxed);
    }

    /// A crashed node restarted (its downtime window closed).
    pub fn node_restart(&self) {
        self.inner.node_restarts.fetch_add(1, Relaxed);
    }

    /// A live worker thread was restarted by its supervisor.
    pub fn worker_restart(&self) {
        self.inner.worker_restarts.fetch_add(1, Relaxed);
    }

    /// An event crossed a shard boundary riding a `CrossShardMsg`.
    pub fn cross_shard_msg(&self) {
        self.inner.cross_shard_msgs.fetch_add(1, Relaxed);
    }

    // ---- adaptation plane ------------------------------------------------

    /// The sink-side controller minted an `AdaptationCommand`.
    pub fn adapt_minted(&self) {
        self.inner.adapt_minted.fetch_add(1, Relaxed);
    }

    /// A command's first broadcast copy applied at the engine's
    /// application point.
    pub fn adapt_applied(&self) {
        self.inner.adapt_applied.fetch_add(1, Relaxed);
    }

    /// A later broadcast copy (or out-of-order delivery) was discarded
    /// as stale.
    pub fn adapt_stale(&self) {
        self.inner.adapt_stale.fetch_add(1, Relaxed);
    }

    // ---- gauges ----------------------------------------------------------

    pub fn set_nodes_down(&self, n: usize) {
        self.inner.nodes_down.store(n as i64, Relaxed);
    }

    /// Publish the engine's shard count K (1 = unsharded).
    pub fn set_shards(&self, k: usize) {
        self.inner.shards.store(k as i64, Relaxed);
    }

    /// Publish how many cameras sit below their native resolution rung.
    pub fn set_cameras_downshifted(&self, n: usize) {
        self.inner.cameras_downshifted.store(n as i64, Relaxed);
    }

    pub fn set_active_cameras(&self, n: usize) {
        self.inner.active_cameras.store(n as i64, Relaxed);
    }

    pub fn set_active_queries(&self, n: usize) {
        self.inner.active_queries.store(n as i64, Relaxed);
    }

    /// Publish the ξ(1) price (µs) a path charges `app` at `stage` —
    /// the per-app ξ gauges behind the live front's multiplier port.
    pub fn set_app_xi(&self, app_index: usize, stage: Stage, xi1_us: Micros) {
        let Some(s) = stage_slot(stage) else { return };
        if app_index < APPS {
            self.inner.xi_app_us[app_index][s].store(xi1_us, Relaxed);
        }
    }

    // ---- per-query counters ---------------------------------------------

    fn with_query<F: FnOnce(&mut QueryCounters)>(&self, q: QueryId, f: F) {
        let mut per = self.inner.per_query.lock().unwrap();
        match per.iter_mut().find(|(id, _)| *id == q) {
            Some((_, c)) => f(c),
            None => {
                let mut c = QueryCounters::default();
                f(&mut c);
                per.push((q, c));
            }
        }
    }

    pub fn query_generated(&self, q: QueryId) {
        self.with_query(q, |c| c.generated += 1);
    }

    pub fn query_completed(&self, q: QueryId, on_time: bool) {
        self.with_query(q, |c| {
            if on_time {
                c.on_time += 1
            } else {
                c.delayed += 1
            }
        });
    }

    pub fn query_dropped(&self, q: QueryId) {
        self.with_query(q, |c| c.dropped += 1);
    }

    pub fn query_lost_to_fault(&self, q: QueryId) {
        self.with_query(q, |c| c.lost_to_fault += 1);
    }

    // ---- per-second dump -------------------------------------------------

    /// Record the cumulative counters as of simulated second `sec`
    /// (DES engines call this once per simulated second, alongside
    /// `Timeline::sample_active`).
    pub fn mark_second(&self, sec: i64) {
        let row = SecondRow {
            sec,
            generated: self.inner.generated.load(Relaxed),
            on_time: self.inner.on_time.load(Relaxed),
            delayed: self.inner.delayed.load(Relaxed),
            dropped: self
                .inner
                .drops_gate
                .iter()
                .map(|c| c.load(Relaxed))
                .sum(),
            batches_va: self.inner.batches[0].load(Relaxed),
            batches_cr: self.inner.batches[1].load(Relaxed),
            active_cameras: self.inner.active_cameras.load(Relaxed),
        };
        self.inner.seconds.lock().unwrap().push(row);
    }

    /// The per-second rows recorded so far.
    pub fn seconds(&self) -> Vec<SecondRow> {
        self.inner.seconds.lock().unwrap().clone()
    }

    // ---- snapshot --------------------------------------------------------

    /// A consistent-enough point-in-time copy (individual atomics are
    /// read independently; exactness holds whenever the engine is
    /// quiescent, e.g. at end of run or between live batches).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let i = &self.inner;
        MetricsSnapshot {
            generated: i.generated.load(Relaxed),
            on_time: i.on_time.load(Relaxed),
            delayed: i.delayed.load(Relaxed),
            detections: i.detections.load(Relaxed),
            drops_gate: [
                i.drops_gate[0].load(Relaxed),
                i.drops_gate[1].load(Relaxed),
                i.drops_gate[2].load(Relaxed),
                i.drops_gate[3].load(Relaxed),
            ],
            batches: [i.batches[0].load(Relaxed), i.batches[1].load(Relaxed)],
            batch_events: [
                i.batch_events[0].load(Relaxed),
                i.batch_events[1].load(Relaxed),
            ],
            batch_hist: [
                HistSnapshot {
                    bounds: BATCH_BOUNDS.iter().map(|&b| b as i64).collect(),
                    counts: i.batch_hist[0].snapshot(),
                },
                HistSnapshot {
                    bounds: BATCH_BOUNDS.iter().map(|&b| b as i64).collect(),
                    counts: i.batch_hist[1].snapshot(),
                },
            ],
            delay_hist: [
                HistSnapshot {
                    bounds: DELAY_BOUNDS_US.to_vec(),
                    counts: i.delay_hist[0].snapshot(),
                },
                HistSnapshot {
                    bounds: DELAY_BOUNDS_US.to_vec(),
                    counts: i.delay_hist[1].snapshot(),
                },
            ],
            xi_observations: i.xi_observations.load(Relaxed),
            nob_retunes: i.nob_retunes.load(Relaxed),
            refinements: i.refinements.load(Relaxed),
            faults_injected: i.faults_injected.load(Relaxed),
            lost_to_fault: i.lost_to_fault.load(Relaxed),
            fault_retries: i.fault_retries.load(Relaxed),
            redispatched: i.redispatched.load(Relaxed),
            node_restarts: i.node_restarts.load(Relaxed),
            worker_restarts: i.worker_restarts.load(Relaxed),
            cross_shard_msgs: i.cross_shard_msgs.load(Relaxed),
            adapt_minted: i.adapt_minted.load(Relaxed),
            adapt_applied: i.adapt_applied.load(Relaxed),
            adapt_stale: i.adapt_stale.load(Relaxed),
            active_cameras: i.active_cameras.load(Relaxed),
            active_queries: i.active_queries.load(Relaxed),
            nodes_down: i.nodes_down.load(Relaxed),
            shards: i.shards.load(Relaxed),
            cameras_downshifted: i.cameras_downshifted.load(Relaxed),
            xi_app_us: std::array::from_fn(|a| {
                std::array::from_fn(|s| i.xi_app_us[a][s].load(Relaxed))
            }),
            per_query: i.per_query.lock().unwrap().clone(),
            seconds: i.seconds.lock().unwrap().clone(),
        }
    }
}

/// Snapshot of one histogram: `counts.len() == bounds.len() + 1` (the
/// final count is the overflow bucket).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub bounds: Vec<i64>,
    pub counts: Vec<u64>,
}

impl HistSnapshot {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    fn to_json(&self) -> Json {
        obj([
            (
                "bounds",
                Json::Arr(
                    self.bounds.iter().map(|&b| Json::from(b)).collect(),
                ),
            ),
            (
                "counts",
                Json::Arr(
                    self.counts
                        .iter()
                        .map(|&c| Json::from(c as i64))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Plain point-in-time copy of every registry metric.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub generated: u64,
    pub on_time: u64,
    pub delayed: u64,
    pub detections: u64,
    /// Indexed by `Gate::id()` (0 = drain, 1..=3 = drop points).
    pub drops_gate: [u64; 4],
    /// `[va, cr]` batch counts.
    pub batches: [u64; 2],
    pub batch_events: [u64; 2],
    pub batch_hist: [HistSnapshot; 2],
    pub delay_hist: [HistSnapshot; 2],
    pub xi_observations: u64,
    pub nob_retunes: u64,
    pub refinements: u64,
    /// Fault transitions fired (0 on failure-free runs).
    pub faults_injected: u64,
    /// Events consumed by faults — mirrors `Summary::lost_to_fault`.
    pub lost_to_fault: u64,
    pub fault_retries: u64,
    pub redispatched: u64,
    pub node_restarts: u64,
    /// Live-front worker threads restarted after a panic.
    pub worker_restarts: u64,
    /// Cross-shard handoff envelopes (sharded DES; 0 at K=1).
    pub cross_shard_msgs: u64,
    /// Adaptation commands minted / applied / discarded-stale (all 0
    /// with the identity ladder).
    pub adapt_minted: u64,
    pub adapt_applied: u64,
    pub adapt_stale: u64,
    pub active_cameras: i64,
    pub active_queries: i64,
    pub nodes_down: i64,
    /// Shard count K published by the engine (0 if never set).
    pub shards: i64,
    /// Cameras currently below their native resolution rung.
    pub cameras_downshifted: i64,
    pub xi_app_us: [[i64; 2]; 4],
    pub per_query: Vec<(QueryId, QueryCounters)>,
    /// Cumulative per-simulated-second rows (empty when
    /// `obs.per_second_metrics` is off or on live paths).
    pub seconds: Vec<SecondRow>,
}

impl MetricsSnapshot {
    pub fn dropped_total(&self) -> u64 {
        self.drops_gate.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        let pq: Vec<Json> = self
            .per_query
            .iter()
            .map(|(q, c)| {
                obj([
                    ("query", (*q as i64).into()),
                    ("generated", (c.generated as i64).into()),
                    ("on_time", (c.on_time as i64).into()),
                    ("delayed", (c.delayed as i64).into()),
                    ("dropped", (c.dropped as i64).into()),
                    (
                        "lost_to_fault",
                        (c.lost_to_fault as i64).into(),
                    ),
                ])
            })
            .collect();
        obj([
            ("generated", (self.generated as i64).into()),
            ("on_time", (self.on_time as i64).into()),
            ("delayed", (self.delayed as i64).into()),
            ("detections", (self.detections as i64).into()),
            (
                "drops_gate",
                Json::Arr(
                    self.drops_gate
                        .iter()
                        .map(|&d| Json::from(d as i64))
                        .collect(),
                ),
            ),
            ("batches_va", (self.batches[0] as i64).into()),
            ("batches_cr", (self.batches[1] as i64).into()),
            ("batch_events_va", (self.batch_events[0] as i64).into()),
            ("batch_events_cr", (self.batch_events[1] as i64).into()),
            ("batch_hist_va", self.batch_hist[0].to_json()),
            ("batch_hist_cr", self.batch_hist[1].to_json()),
            ("delay_hist_va", self.delay_hist[0].to_json()),
            ("delay_hist_cr", self.delay_hist[1].to_json()),
            ("xi_observations", (self.xi_observations as i64).into()),
            ("nob_retunes", (self.nob_retunes as i64).into()),
            ("refinements", (self.refinements as i64).into()),
            ("faults_injected", (self.faults_injected as i64).into()),
            ("lost_to_fault", (self.lost_to_fault as i64).into()),
            ("fault_retries", (self.fault_retries as i64).into()),
            ("redispatched", (self.redispatched as i64).into()),
            ("node_restarts", (self.node_restarts as i64).into()),
            ("worker_restarts", (self.worker_restarts as i64).into()),
            (
                "cross_shard_msgs",
                (self.cross_shard_msgs as i64).into(),
            ),
            ("adapt_minted", (self.adapt_minted as i64).into()),
            ("adapt_applied", (self.adapt_applied as i64).into()),
            ("adapt_stale", (self.adapt_stale as i64).into()),
            ("active_cameras", self.active_cameras.into()),
            ("active_queries", self.active_queries.into()),
            ("nodes_down", self.nodes_down.into()),
            ("shards", self.shards.into()),
            (
                "cameras_downshifted",
                self.cameras_downshifted.into(),
            ),
            (
                "xi_app_us",
                Json::Arr(
                    self.xi_app_us
                        .iter()
                        .map(|row| {
                            Json::Arr(
                                row.iter()
                                    .map(|&v| Json::from(v))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            ("per_query", Json::Arr(pq)),
            (
                "seconds",
                Json::Arr(
                    self.seconds
                        .iter()
                        .map(|s| {
                            obj([
                                ("sec", s.sec.into()),
                                (
                                    "generated",
                                    (s.generated as i64).into(),
                                ),
                                ("on_time", (s.on_time as i64).into()),
                                ("delayed", (s.delayed as i64).into()),
                                ("dropped", (s.dropped as i64).into()),
                                (
                                    "batches_va",
                                    (s.batches_va as i64).into(),
                                ),
                                (
                                    "batches_cr",
                                    (s.batches_cr as i64).into(),
                                ),
                                (
                                    "active_cameras",
                                    s.active_cameras.into(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gates() {
        let m = MetricsRegistry::new();
        m.generated();
        m.generated();
        m.completed(true);
        m.dropped(Gate::Exec);
        m.dropped(Gate::Exec);
        m.dropped(Gate::Queue);
        let s = m.snapshot();
        assert_eq!(s.generated, 2);
        assert_eq!(s.on_time, 1);
        assert_eq!(s.drops_gate[Gate::Exec.id() as usize], 2);
        assert_eq!(s.drops_gate[Gate::Queue.id() as usize], 1);
        assert_eq!(s.dropped_total(), 3);
    }

    #[test]
    fn batch_histograms_bucket_correctly() {
        let m = MetricsRegistry::new();
        m.batch_executed(Stage::Va, 1, 500);
        m.batch_executed(Stage::Va, 25, 20 * SEC); // delay overflows
        m.batch_executed(Stage::Va, 40, MS); // size overflows
        m.batch_executed(Stage::Cr, 8, 2 * SEC);
        m.batch_executed(Stage::Fc, 3, 0); // ignored: not an exec stage
        let s = m.snapshot();
        assert_eq!(s.batches, [3, 1]);
        assert_eq!(s.batch_events, [66, 8]);
        let va = &s.batch_hist[0];
        assert_eq!(va.counts[0], 1); // b=1
        assert_eq!(va.counts[BATCH_BOUNDS.len() - 1], 1); // b=25
        assert_eq!(*va.counts.last().unwrap(), 1); // b=40 overflow
        assert_eq!(va.total(), 3);
        assert_eq!(*s.delay_hist[0].counts.last().unwrap(), 1);
    }

    #[test]
    fn per_query_counters_accumulate() {
        let m = MetricsRegistry::new();
        m.query_generated(3);
        m.query_generated(3);
        m.query_completed(3, true);
        m.query_dropped(7);
        let s = m.snapshot();
        assert_eq!(s.per_query.len(), 2);
        let q3 = s.per_query.iter().find(|(q, _)| *q == 3).unwrap().1;
        assert_eq!((q3.generated, q3.on_time), (2, 1));
    }

    #[test]
    fn second_rows_are_cumulative() {
        let m = MetricsRegistry::new();
        m.generated();
        m.set_active_cameras(5);
        m.mark_second(0);
        m.generated();
        m.mark_second(1);
        let rows = m.seconds();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].generated, 1);
        assert_eq!(rows[1].generated, 2);
        assert_eq!(rows[0].active_cameras, 5);
    }

    #[test]
    fn fault_counters_accumulate() {
        let m = MetricsRegistry::new();
        m.fault_injected();
        m.lost_to_fault();
        m.lost_to_fault();
        m.fault_retry();
        m.redispatched(5);
        m.node_restart();
        m.worker_restart();
        m.cross_shard_msg();
        m.cross_shard_msg();
        m.cross_shard_msg();
        m.set_nodes_down(2);
        m.set_shards(4);
        m.query_lost_to_fault(4);
        let s = m.snapshot();
        assert_eq!(s.faults_injected, 1);
        assert_eq!(s.lost_to_fault, 2);
        assert_eq!(s.fault_retries, 1);
        assert_eq!(s.redispatched, 5);
        assert_eq!(s.node_restarts, 1);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.cross_shard_msgs, 3);
        assert_eq!(s.nodes_down, 2);
        assert_eq!(s.shards, 4);
        let q4 = s.per_query.iter().find(|(q, _)| *q == 4).unwrap().1;
        assert_eq!(q4.lost_to_fault, 1);
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.at("lost_to_fault").as_usize(), Some(2));
        assert_eq!(j.at("cross_shard_msgs").as_usize(), Some(3));
        assert_eq!(j.at("shards").as_usize(), Some(4));
    }

    #[test]
    fn adaptation_counters_accumulate() {
        let m = MetricsRegistry::new();
        m.adapt_minted();
        m.adapt_applied();
        m.adapt_stale();
        m.adapt_stale();
        m.set_cameras_downshifted(3);
        let s = m.snapshot();
        assert_eq!(s.adapt_minted, 1);
        assert_eq!(s.adapt_applied, 1);
        assert_eq!(s.adapt_stale, 2);
        assert_eq!(s.cameras_downshifted, 3);
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.at("adapt_minted").as_usize(), Some(1));
        assert_eq!(j.at("adapt_stale").as_usize(), Some(2));
        assert_eq!(j.at("cameras_downshifted").as_usize(), Some(3));
    }

    #[test]
    fn app_xi_gauges() {
        let m = MetricsRegistry::new();
        m.set_app_xi(1, Stage::Cr, 195_600);
        let s = m.snapshot();
        assert_eq!(s.xi_app_us[1][1], 195_600);
        assert_eq!(s.xi_app_us[0][0], 0);
        // Snapshot JSON round-trips through the codec.
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(j.at("generated").as_usize(), Some(0));
    }
}
