//! The flight recorder: a fixed-capacity ring of the newest trace
//! events.
//!
//! Capacity is required to be prime — the same lesson the
//! `BudgetManager` rings encode (4093/251/2039): a prime capacity
//! cannot resonate with any periodic event pattern, so systematic
//! strides never alias onto the same slots. The default matches the
//! coordinator task ring (4093).

use std::sync::{Arc, Mutex};

use crate::obs::{ObsSink, Scope, SpanStats, TraceEvent};
use crate::util::Micros;

/// How many of the newest ring events a panic dump prints.
const PANIC_DUMP_TAIL: usize = 64;

/// Default ring capacity (prime; mirrors `BudgetManager`'s task ring).
pub const DEFAULT_RING_CAPACITY: usize = 4093;

fn is_prime(n: usize) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

struct Ring {
    /// Slot storage; grows to capacity then stays fixed.
    slots: Vec<(Micros, TraceEvent)>,
    /// Next write position once `slots` is full.
    head: usize,
    /// Total events ever emitted (≥ `slots.len()`).
    total: u64,
}

/// Fixed-capacity in-memory flight recorder. Cheap to clone (shared
/// `Arc` innards); keeps the newest `capacity` events and all profiling
/// spans.
#[derive(Clone)]
pub struct RingSink {
    ring: Arc<Mutex<Ring>>,
    capacity: usize,
    spans: Arc<SpanStats>,
}

impl Default for RingSink {
    fn default() -> Self {
        Self::new(DEFAULT_RING_CAPACITY)
    }
}

impl RingSink {
    /// Create a recorder holding the newest `capacity` events.
    /// Panics unless `capacity` is prime (see module docs).
    pub fn new(capacity: usize) -> Self {
        assert!(
            is_prime(capacity),
            "RingSink capacity must be prime, got {capacity}"
        );
        Self {
            ring: Arc::new(Mutex::new(Ring {
                slots: Vec::with_capacity(capacity),
                head: 0,
                total: 0,
            })),
            capacity,
            spans: Arc::new(SpanStats::default()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total events ever emitted (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.ring.lock().unwrap().total
    }

    /// The retained events, oldest first, newest last. Never more than
    /// `capacity` entries; once full, always exactly the newest
    /// `capacity` events in emission order.
    pub fn events(&self) -> Vec<(Micros, TraceEvent)> {
        let r = self.ring.lock().unwrap();
        if r.slots.len() < self.capacity {
            r.slots.clone()
        } else {
            let mut out = Vec::with_capacity(self.capacity);
            out.extend_from_slice(&r.slots[r.head..]);
            out.extend_from_slice(&r.slots[..r.head]);
            out
        }
    }

    /// The profiling span accumulators (shared with clones).
    pub fn spans(&self) -> &SpanStats {
        &self.spans
    }

    /// Render the newest ring events as one human-readable block — the
    /// "black box" read-out printed when something dies.
    pub fn dump_tail(&self, max: usize) -> String {
        let evs = self.events();
        let skip = evs.len().saturating_sub(max);
        let mut out = String::new();
        out.push_str(&format!(
            "--- flight recorder: newest {} of {} events ---\n",
            evs.len() - skip,
            self.total()
        ));
        for (t, ev) in &evs[skip..] {
            out.push_str(&format!("  [{t:>12}us] {}\n", ev.to_json(*t).to_string()));
        }
        out
    }

    /// Chain a panic hook that dumps the newest ring events to stderr
    /// before the default hook runs. Crash forensics for the harness
    /// and the live-path worker supervisor: whatever the process was
    /// doing in its last few thousand events survives the panic.
    ///
    /// The clone registered here shares the recorder, so events emitted
    /// after installation are visible to the dump.
    pub fn install_dump_on_panic(&self) {
        let ring = self.clone();
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            eprintln!("{}", ring.dump_tail(PANIC_DUMP_TAIL));
            prev(info);
        }));
    }
}

impl ObsSink for RingSink {
    #[inline]
    fn enabled(&self) -> bool {
        true
    }

    fn emit(&self, t: Micros, ev: &TraceEvent) {
        let mut r = self.ring.lock().unwrap();
        r.total += 1;
        if r.slots.len() < self.capacity {
            r.slots.push((t, ev.clone()));
        } else {
            let head = r.head;
            r.slots[head] = (t, ev.clone());
            r.head = (head + 1) % self.capacity;
        }
    }

    fn profiled(&self) -> bool {
        true
    }

    fn record_span(&self, scope: Scope, ns: u64) {
        self.spans.record(scope, ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(event: u64) -> TraceEvent {
        TraceEvent::Generated { event, query: 0, camera: 0 }
    }

    #[test]
    fn primality_check() {
        for p in [2, 3, 5, 251, 2039, 4093] {
            assert!(is_prime(p), "{p} is prime");
        }
        for c in [0, 1, 4, 9, 4095, 4096] {
            assert!(!is_prime(c), "{c} is composite");
        }
    }

    #[test]
    #[should_panic(expected = "must be prime")]
    fn composite_capacity_rejected() {
        RingSink::new(4096);
    }

    #[test]
    fn below_capacity_keeps_everything_in_order() {
        let s = RingSink::new(7);
        for i in 0..5 {
            s.emit(i as Micros, &gen(i));
        }
        let evs = s.events();
        assert_eq!(evs.len(), 5);
        assert_eq!(s.total(), 5);
        for (i, (t, ev)) in evs.iter().enumerate() {
            assert_eq!(*t, i as Micros);
            assert_eq!(*ev, gen(i as u64));
        }
    }

    #[test]
    fn wraparound_keeps_newest_in_order() {
        let s = RingSink::new(7);
        for i in 0..23 {
            s.emit(i as Micros, &gen(i));
        }
        let evs = s.events();
        assert_eq!(evs.len(), 7);
        assert_eq!(s.total(), 23);
        // Exactly the newest 7, oldest first.
        for (k, (t, ev)) in evs.iter().enumerate() {
            let want = 16 + k as u64;
            assert_eq!(*t, want as Micros);
            assert_eq!(*ev, gen(want));
        }
    }

    #[test]
    fn dump_tail_renders_newest_events() {
        let s = RingSink::new(7);
        for i in 0..10 {
            s.emit(i as Micros, &gen(i));
        }
        let d = s.dump_tail(3);
        assert!(d.contains("newest 3 of 10 events"));
        // Only the last three survive the tail cut.
        assert!(!d.contains("\"event\":6"));
        for want in ["\"event\":7", "\"event\":8", "\"event\":9"] {
            assert!(d.contains(want), "missing {want} in {d}");
        }
    }

    #[test]
    fn clones_share_the_recorder() {
        let s = RingSink::new(5);
        let c = s.clone();
        s.emit(1, &gen(1));
        c.emit(2, &gen(2));
        assert_eq!(s.total(), 2);
        assert_eq!(c.events().len(), 2);
        c.record_span(Scope::Scoring, 10);
        assert_eq!(s.spans().rows()[Scope::Scoring.index()].1, 1);
    }
}
