//! In-repo observability: structured trace events, a metrics registry,
//! and wall-clock profiling spans — the flight recorder behind
//! `harness trace` and the per-stage breakdowns.
//!
//! The offline build has no `tracing`/`metrics` crates, so the layer is
//! hand-rolled in the vendored-`anyhow` spirit: a compact [`TraceEvent`]
//! enum, an [`ObsSink`] trait threaded through all four execution paths
//! (both DES engines, the live engine, the multi-query front), and
//! three sinks:
//!
//! * [`NullSink`] — the default. `enabled()` returns a constant `false`
//!   and every call site guards event *construction* behind it, so the
//!   whole layer inlines to nothing: per-seed bit-identity and RNG draw
//!   counts are provably untouched (`prop_obs` asserts this against
//!   [`crate::util::Rng::draws`]).
//! * [`RingSink`] — a fixed-capacity in-memory flight recorder holding
//!   the newest events (prime capacity, per the `BudgetManager` ring
//!   lesson).
//! * [`JsonlSink`] — schema-versioned JSONL export ([`TRACE_SCHEMA`]),
//!   hand-rolled over [`crate::util::Json`] like `config/io.rs`.
//!
//! The metrics side ([`MetricsRegistry`]) is plain atomics behind a
//! cheaply clonable handle: counters, gauges and fixed-bucket
//! histograms for the tuning triangle, snapshotable mid-run from the
//! live service and dumped per simulated second by the DES engines.

pub mod jsonl;
pub mod registry;
pub mod report;
pub mod ring;

pub use jsonl::{validate_trace, JsonlSink, TraceCheck};
pub use registry::{
    HistSnapshot, MetricsRegistry, MetricsSnapshot, QueryCounters,
    SecondRow,
};
pub use report::{render_rows, ReportRow};
pub use ring::RingSink;

use std::time::Instant;

use crate::dataflow::{QueryId, Stage};
use crate::util::json::obj;
use crate::util::{Json, Micros};

/// Trace schema identifier written as the first JSONL line and checked
/// by CI's trace-validation step. Bump on any breaking field change.
/// v2: fault-injection kinds (`node_fault`, `camera_fault`,
/// `lost_to_fault`, `fault_retry`, `redispatch`) — `lost_to_fault` is a
/// new *terminal*, so a v1 validator would miscount conservation.
/// v3: the `cross_shard` kind — sharded-DES boundary handoffs (not a
/// terminal; conservation arithmetic is unchanged, but a v2 validator
/// would reject the unknown kind).
/// v4: the `adaptation` kind — accuracy–latency commands applied on
/// the feedback edge (not a terminal; conservation unchanged, but a
/// v3 validator would reject the unknown kind).
pub const TRACE_SCHEMA: &str = "anveshak-trace-v4";

/// Which of the three §4.3 drop points produced a verdict (plus the
/// teardown pseudo-gate for events drained without a budget decision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// Engine teardown: a query ended while events were still queued —
    /// no budget arithmetic was involved.
    Drain,
    /// Drop point 1 — on arrival, before queueing (the FC source gate
    /// uses the same arithmetic with u = 0).
    Queue,
    /// Drop point 2 — the batch-formation filter.
    Exec,
    /// Drop point 3 — post-execution, before transmit.
    Transmit,
}

impl Gate {
    /// Stable numeric id (0 = drain, 1..=3 = the paper's drop points).
    pub fn id(self) -> u8 {
        match self {
            Gate::Drain => 0,
            Gate::Queue => 1,
            Gate::Exec => 2,
            Gate::Transmit => 3,
        }
    }

    pub fn from_id(id: u8) -> Option<Gate> {
        match id {
            0 => Some(Gate::Drain),
            1 => Some(Gate::Queue),
            2 => Some(Gate::Exec),
            3 => Some(Gate::Transmit),
            _ => None,
        }
    }
}

/// Query lifecycle phases traced by the multi-query paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryPhase {
    Submitted,
    Admitted,
    Queued,
    Rejected,
    Activated,
    Completed,
    Cancelled,
}

impl QueryPhase {
    pub fn label(self) -> &'static str {
        match self {
            QueryPhase::Submitted => "submitted",
            QueryPhase::Admitted => "admitted",
            QueryPhase::Queued => "queued",
            QueryPhase::Rejected => "rejected",
            QueryPhase::Activated => "activated",
            QueryPhase::Completed => "completed",
            QueryPhase::Cancelled => "cancelled",
        }
    }
}

/// Profiled hot-path scopes (wall-clock attribution, never virtual
/// time — spans exist for the human reading `harness` output and are
/// invisible to the simulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// TL spotlight expansion (`active_set_into`).
    SpotlightExpand,
    /// VA/CR scoring / simulated block stepping over a batch.
    Scoring,
    /// Batcher poll loop (form/timer decisions).
    BatchPoll,
    /// Engine event dispatch (one simulation event or worker message).
    Dispatch,
    /// PJRT / score-backend model execution (live paths).
    ModelExec,
    /// One live feed-loop iteration (frame generation + FC + dispatch).
    FeedLoop,
}

/// All scopes, in display order.
pub const SCOPES: [Scope; 6] = [
    Scope::Dispatch,
    Scope::BatchPoll,
    Scope::Scoring,
    Scope::SpotlightExpand,
    Scope::ModelExec,
    Scope::FeedLoop,
];

impl Scope {
    pub fn index(self) -> usize {
        match self {
            Scope::Dispatch => 0,
            Scope::BatchPoll => 1,
            Scope::Scoring => 2,
            Scope::SpotlightExpand => 3,
            Scope::ModelExec => 4,
            Scope::FeedLoop => 5,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Scope::Dispatch => "dispatch",
            Scope::BatchPoll => "batch_poll",
            Scope::Scoring => "scoring",
            Scope::SpotlightExpand => "spotlight_expand",
            Scope::ModelExec => "model_exec",
            Scope::FeedLoop => "feed_loop",
        }
    }
}

fn stage_str(s: Stage) -> &'static str {
    match s {
        Stage::Fc => "fc",
        Stage::Va => "va",
        Stage::Cr => "cr",
        Stage::Tl => "tl",
        Stage::Qf => "qf",
        Stage::Uv => "uv",
    }
}

/// One structured trace event. Compact by design: fixed-size fields
/// only (the ring sink stores millions without allocation churn).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A source event entered the dataflow.
    Generated { event: u64, query: QueryId, camera: u32 },
    /// A drop gate fired. `eps_us` is the lateness that triggered the
    /// verdict and `xi_us` the ξ estimate used, so the human
    /// explanation is reconstructible: slack = `xi_us - eps_us` was
    /// smaller than ξ(b). `batch` is the b the gate priced (1 at gate
    /// 1/3).
    Drop {
        gate: Gate,
        stage: Stage,
        event: u64,
        query: QueryId,
        batch: u32,
        eps_us: Micros,
        xi_us: Micros,
    },
    /// An exempt (avoid-drop/probe) event survived a gate that would
    /// have dropped it — the §4.3.3 exemption observed in the wild.
    Exempted { gate: Gate, stage: Stage, event: u64, query: QueryId },
    /// A batch left the batcher for execution.
    BatchFormed { stage: Stage, task: u32, size: u32 },
    /// A batch finished executing (estimated vs actual duration).
    BatchExecuted {
        stage: Stage,
        task: u32,
        size: u32,
        est_us: Micros,
        actual_us: Micros,
    },
    /// Online ξ recalibration consumed an observation; `alpha_us` and
    /// `beta_us` are the refined coefficients after the EMA step.
    XiObserved {
        stage: Stage,
        task: u32,
        b_eff: f64,
        actual_us: Micros,
        alpha_us: f64,
        beta_us: f64,
    },
    /// The executor retuned its NOB lookup table against refreshed ξ.
    NobRetune { stage: Stage, task: u32 },
    /// A QF refinement was routed back upstream (the feedback edge).
    RefinementApplied { query: QueryId, seq: u32 },
    /// Query lifecycle transition (multi-query paths).
    QueryLifecycle { query: QueryId, phase: QueryPhase },
    /// TL spotlight resize: the active camera set changed size.
    Spotlight { query: QueryId, active: u32 },
    /// Scheduled compute dynamism step (node = -1 means all nodes).
    ComputeFactor { node: i64, factor: f64 },
    /// Scheduled network bandwidth step.
    Bandwidth { bps: f64 },
    /// An event reached the sink.
    Completed {
        event: u64,
        query: QueryId,
        latency_us: Micros,
        on_time: bool,
        detected: bool,
    },
    /// A cluster node crashed (`up: false`) or restarted (`up: true`).
    NodeFault { node: u32, up: bool },
    /// A camera went dark (`up: false`) or came back (`up: true`).
    CameraFault { camera: u32, up: bool },
    /// A source event was consumed by an injected fault — the new
    /// conservation terminal next to `drop` and `completed`.
    LostToFault { event: u64, query: QueryId, stage: Stage },
    /// Recovery retried a fault-hit event (bounded exponential
    /// backoff); `attempt` is 0-based.
    FaultRetry { event: u64, query: QueryId, attempt: u32 },
    /// Recovery re-dispatched orphaned events from a dead executor to
    /// a surviving one.
    Redispatch {
        stage: Stage,
        from_task: u32,
        to_task: u32,
        events: u32,
    },
    /// A sharded-DES handoff: an event scheduled across a shard
    /// boundary rode a [`crate::engine::CrossShardMsg`] envelope.
    /// `seq` is the global merge sequence number of the handed-off
    /// event.
    CrossShard { from_shard: u32, to_shard: u32, seq: u64 },
    /// An [`crate::tuning::adapt::AdaptationCommand`] was *applied* at
    /// its single application point: `camera` now runs resolution rung
    /// `level` with model `variant`. Stale broadcast copies emit
    /// nothing (the stale counter in the metrics registry tracks
    /// them), so one applied line per minted command.
    Adaptation {
        camera: u32,
        seq: u32,
        level: u32,
        variant: &'static str,
    },
}

impl TraceEvent {
    /// Stable kind tag (the JSONL `ev` field).
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Generated { .. } => "generated",
            TraceEvent::Drop { .. } => "drop",
            TraceEvent::Exempted { .. } => "exempted",
            TraceEvent::BatchFormed { .. } => "batch_formed",
            TraceEvent::BatchExecuted { .. } => "batch_executed",
            TraceEvent::XiObserved { .. } => "xi_observed",
            TraceEvent::NobRetune { .. } => "nob_retune",
            TraceEvent::RefinementApplied { .. } => "refinement",
            TraceEvent::QueryLifecycle { .. } => "query",
            TraceEvent::Spotlight { .. } => "spotlight",
            TraceEvent::ComputeFactor { .. } => "compute_factor",
            TraceEvent::Bandwidth { .. } => "bandwidth",
            TraceEvent::Completed { .. } => "completed",
            TraceEvent::NodeFault { .. } => "node_fault",
            TraceEvent::CameraFault { .. } => "camera_fault",
            TraceEvent::LostToFault { .. } => "lost_to_fault",
            TraceEvent::FaultRetry { .. } => "fault_retry",
            TraceEvent::Redispatch { .. } => "redispatch",
            TraceEvent::CrossShard { .. } => "cross_shard",
            TraceEvent::Adaptation { .. } => "adaptation",
        }
    }

    /// JSONL line body (timestamp + kind + per-kind fields), in the
    /// `config/io.rs` hand-rolled style.
    pub fn to_json(&self, t: Micros) -> Json {
        let base = [("t_us", Json::from(t)), ("ev", self.kind().into())];
        let mut m = match obj(base) {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        match self {
            TraceEvent::Generated { event, query, camera } => {
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
                put("camera", (*camera as i64).into());
            }
            TraceEvent::Drop {
                gate,
                stage,
                event,
                query,
                batch,
                eps_us,
                xi_us,
            } => {
                put("gate", (gate.id() as i64).into());
                put("stage", stage_str(*stage).into());
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
                put("batch", (*batch as i64).into());
                put("eps_us", (*eps_us).into());
                put("xi_us", (*xi_us).into());
            }
            TraceEvent::Exempted { gate, stage, event, query } => {
                put("gate", (gate.id() as i64).into());
                put("stage", stage_str(*stage).into());
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
            }
            TraceEvent::BatchFormed { stage, task, size } => {
                put("stage", stage_str(*stage).into());
                put("task", (*task as i64).into());
                put("size", (*size as i64).into());
            }
            TraceEvent::BatchExecuted {
                stage,
                task,
                size,
                est_us,
                actual_us,
            } => {
                put("stage", stage_str(*stage).into());
                put("task", (*task as i64).into());
                put("size", (*size as i64).into());
                put("est_us", (*est_us).into());
                put("actual_us", (*actual_us).into());
            }
            TraceEvent::XiObserved {
                stage,
                task,
                b_eff,
                actual_us,
                alpha_us,
                beta_us,
            } => {
                put("stage", stage_str(*stage).into());
                put("task", (*task as i64).into());
                put("b_eff", (*b_eff).into());
                put("actual_us", (*actual_us).into());
                put("alpha_us", (*alpha_us).into());
                put("beta_us", (*beta_us).into());
            }
            TraceEvent::NobRetune { stage, task } => {
                put("stage", stage_str(*stage).into());
                put("task", (*task as i64).into());
            }
            TraceEvent::RefinementApplied { query, seq } => {
                put("query", (*query as i64).into());
                put("seq", (*seq as i64).into());
            }
            TraceEvent::QueryLifecycle { query, phase } => {
                put("query", (*query as i64).into());
                put("phase", phase.label().into());
            }
            TraceEvent::Spotlight { query, active } => {
                put("query", (*query as i64).into());
                put("active", (*active as i64).into());
            }
            TraceEvent::ComputeFactor { node, factor } => {
                put("node", (*node).into());
                put("factor", (*factor).into());
            }
            TraceEvent::Bandwidth { bps } => {
                put("bps", (*bps).into());
            }
            TraceEvent::Completed {
                event,
                query,
                latency_us,
                on_time,
                detected,
            } => {
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
                put("latency_us", (*latency_us).into());
                put("on_time", (*on_time).into());
                put("detected", (*detected).into());
            }
            TraceEvent::NodeFault { node, up } => {
                put("node", (*node as i64).into());
                put("up", (*up).into());
            }
            TraceEvent::CameraFault { camera, up } => {
                put("camera", (*camera as i64).into());
                put("up", (*up).into());
            }
            TraceEvent::LostToFault { event, query, stage } => {
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
                put("stage", stage_str(*stage).into());
            }
            TraceEvent::FaultRetry { event, query, attempt } => {
                put("event", (*event as i64).into());
                put("query", (*query as i64).into());
                put("attempt", (*attempt as i64).into());
            }
            TraceEvent::Redispatch {
                stage,
                from_task,
                to_task,
                events,
            } => {
                put("stage", stage_str(*stage).into());
                put("from_task", (*from_task as i64).into());
                put("to_task", (*to_task as i64).into());
                put("events", (*events as i64).into());
            }
            TraceEvent::CrossShard { from_shard, to_shard, seq } => {
                put("from_shard", (*from_shard as i64).into());
                put("to_shard", (*to_shard as i64).into());
                put("seq", (*seq as i64).into());
            }
            TraceEvent::Adaptation { camera, seq, level, variant } => {
                put("camera", (*camera as i64).into());
                put("seq", (*seq as i64).into());
                put("level", (*level as i64).into());
                put("variant", (*variant).into());
            }
        }
        Json::Obj(m)
    }
}

/// A trace sink. Implementations must be cheap clonable handles
/// (`Arc` innards) so the live paths can share one recorder across
/// worker threads; the DES engines are generic over `S: ObsSink`, so
/// the [`NullSink`] default monomorphizes every hook away.
pub trait ObsSink: Send + Sync {
    /// Fast guard: call sites skip event *construction* when false.
    fn enabled(&self) -> bool;

    /// Record one trace event at virtual (DES) or wall (live) time `t`.
    fn emit(&self, t: Micros, ev: &TraceEvent);

    /// Whether wall-clock profiling spans should be timed at all.
    fn profiled(&self) -> bool {
        false
    }

    /// Attribute `ns` nanoseconds of wall-clock to `scope`.
    fn record_span(&self, scope: Scope, ns: u64) {
        let _ = (scope, ns);
    }

    /// RAII scope timer: times from construction to drop, reporting
    /// through [`ObsSink::record_span`]. A no-op (no clock read) when
    /// `profiled()` is false.
    fn span(&self, scope: Scope) -> SpanGuard<'_>
    where
        Self: Sized,
    {
        SpanGuard::start(self, scope)
    }
}

/// The default sink: everything compiles to nothing. The determinism
/// contract (per-seed bit-identity, fixed RNG draw counts) is stated —
/// and property-tested — against this sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl ObsSink for NullSink {
    #[inline(always)]
    fn enabled(&self) -> bool {
        false
    }

    #[inline(always)]
    fn emit(&self, _t: Micros, _ev: &TraceEvent) {}
}

/// Tee: fan one trace out to two sinks. `(JsonlSink, RingSink)` gives
/// the harness a durable on-disk trace *and* a crash-forensics ring
/// (see [`RingSink::install_dump_on_panic`]) from a single run.
/// Profiling spans go to the first sink that profiles, so a pair never
/// double-counts wall-clock.
impl<A: ObsSink, B: ObsSink> ObsSink for (A, B) {
    #[inline]
    fn enabled(&self) -> bool {
        self.0.enabled() || self.1.enabled()
    }

    fn emit(&self, t: Micros, ev: &TraceEvent) {
        if self.0.enabled() {
            self.0.emit(t, ev);
        }
        if self.1.enabled() {
            self.1.emit(t, ev);
        }
    }

    fn profiled(&self) -> bool {
        self.0.profiled() || self.1.profiled()
    }

    fn record_span(&self, scope: Scope, ns: u64) {
        if self.0.profiled() {
            self.0.record_span(scope, ns);
        } else {
            self.1.record_span(scope, ns);
        }
    }
}

/// RAII scope timer (see [`ObsSink::span`]).
pub struct SpanGuard<'a> {
    sink: &'a dyn ObsSink,
    scope: Scope,
    start: Option<Instant>,
}

impl<'a> SpanGuard<'a> {
    pub fn start(sink: &'a dyn ObsSink, scope: Scope) -> Self {
        let start = sink.profiled().then(Instant::now);
        Self { sink, scope, start }
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            self.sink
                .record_span(self.scope, t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Manual span start for `&mut self` hot paths where an RAII guard
/// would hold a whole-struct borrow: returns a clock reading only when
/// the sink profiles. Pair with [`span_end`].
#[inline]
pub fn span_begin(sink: &dyn ObsSink) -> Option<Instant> {
    sink.profiled().then(Instant::now)
}

/// Close a [`span_begin`] reading, attributing the elapsed wall-clock.
#[inline]
pub fn span_end(sink: &dyn ObsSink, scope: Scope, t0: Option<Instant>) {
    if let Some(t0) = t0 {
        sink.record_span(scope, t0.elapsed().as_nanos() as u64);
    }
}

/// Per-scope wall-clock accumulators shared by the recording sinks.
#[derive(Debug, Default)]
pub struct SpanStats {
    counts: [std::sync::atomic::AtomicU64; SCOPES.len()],
    total_ns: [std::sync::atomic::AtomicU64; SCOPES.len()],
}

impl SpanStats {
    pub fn record(&self, scope: Scope, ns: u64) {
        use std::sync::atomic::Ordering::Relaxed;
        let i = scope.index();
        self.counts[i].fetch_add(1, Relaxed);
        self.total_ns[i].fetch_add(ns, Relaxed);
    }

    /// `(scope, invocations, total ns)` rows in display order.
    pub fn rows(&self) -> Vec<(Scope, u64, u64)> {
        use std::sync::atomic::Ordering::Relaxed;
        SCOPES
            .iter()
            .map(|&s| {
                (
                    s,
                    self.counts[s.index()].load(Relaxed),
                    self.total_ns[s.index()].load(Relaxed),
                )
            })
            .collect()
    }

    /// Human-readable stage-attributed breakdown (the `harness`
    /// profiling table). Empty string when nothing was recorded.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let rows = self.rows();
        if rows.iter().all(|&(_, n, _)| n == 0) {
            return String::new();
        }
        let mut out = String::from(
            "  scope              calls        total      mean\n",
        );
        for (scope, n, ns) in rows {
            if n == 0 {
                continue;
            }
            let _ = writeln!(
                out,
                "  {:<16} {:>8}  {:>9.3} s  {:>6.1} us",
                scope.label(),
                n,
                ns as f64 / 1e9,
                ns as f64 / 1e3 / n as f64,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_ids_round_trip() {
        for g in [Gate::Drain, Gate::Queue, Gate::Exec, Gate::Transmit]
        {
            assert_eq!(Gate::from_id(g.id()), Some(g));
        }
        assert_eq!(Gate::from_id(9), None);
    }

    #[test]
    fn null_sink_is_disabled_and_span_free() {
        let s = NullSink;
        assert!(!s.enabled());
        assert!(!s.profiled());
        // No clock read happens: the guard's start stays None.
        let g = s.span(Scope::Dispatch);
        assert!(g.start.is_none());
        assert!(span_begin(&s).is_none());
    }

    #[test]
    fn trace_event_json_has_kind_and_time() {
        let ev = TraceEvent::Drop {
            gate: Gate::Exec,
            stage: Stage::Cr,
            event: 42,
            query: 0,
            batch: 4,
            eps_us: 6_000,
            xi_us: 18_000,
        };
        let j = ev.to_json(1_500_000);
        assert_eq!(j.at("ev").as_str(), Some("drop"));
        assert_eq!(j.at("t_us").as_usize(), Some(1_500_000));
        assert_eq!(j.at("gate").as_usize(), Some(2));
        assert_eq!(j.at("stage").as_str(), Some("cr"));
        // Round-trips through the hand-rolled codec.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.at("xi_us").as_usize(), Some(18_000));
    }

    #[test]
    fn span_stats_accumulate_and_render() {
        let st = SpanStats::default();
        st.record(Scope::BatchPoll, 1_000);
        st.record(Scope::BatchPoll, 3_000);
        let rows = st.rows();
        let bp = rows
            .iter()
            .find(|(s, _, _)| *s == Scope::BatchPoll)
            .unwrap();
        assert_eq!((bp.1, bp.2), (2, 4_000));
        assert!(st.render().contains("batch_poll"));
    }
}
