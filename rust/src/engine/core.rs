//! The shared zero-allocation discrete-event core.
//!
//! Both virtual-time engines — the single-query
//! [`crate::coordinator::des::DesEngine`] and the multi-query
//! [`crate::service::engine::MultiQueryDes`] — used to carry their own
//! copy of the same event plumbing: a slab of event payloads, a
//! `BinaryHeap` of `(time, seq, slot)` keys, a free-list, a sequence
//! counter for FIFO tie-breaking, and the pop-advance-dispatch loop.
//! [`EventCore`] is that plumbing extracted once, generic over the
//! engine's event enum.
//!
//! Design notes:
//!
//! * **Slab-indexed storage.** Heap entries are 24-byte
//!   `(Reverse<Micros>, Reverse<u64>, u32)` keys; the (potentially
//!   large) event payloads never move while queued. Freed slots are
//!   recycled through a free-list, so a steady-state run performs no
//!   per-event heap allocation: the slab and the binary heap reach
//!   their high-water capacity once and stay there.
//! * **Deterministic ordering.** Ties on the timestamp are broken by
//!   the monotone sequence number, exactly like the per-engine
//!   implementations this replaces — event order (and therefore every
//!   RNG draw downstream of it) is bit-identical.
//! * **Monotone time.** `schedule` clamps timestamps to `now`, so a
//!   handler can never schedule into the past.
//!
//! The engines keep their own `dispatch(ev)` match — the event
//! vocabularies differ — but the loop itself is two lines:
//!
//! ```ignore
//! while let Some((t, ev)) = self.core.pop_until(horizon) {
//!     self.now = t;
//!     self.dispatch(ev);
//! }
//! ```

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::Micros;

/// Slab-indexed binary-heap event queue shared by the DES engines.
pub struct EventCore<E> {
    /// Min-heap over `(time, sequence)` via `Reverse`; payload index
    /// into `store`.
    heap: BinaryHeap<(Reverse<Micros>, Reverse<u64>, u32)>,
    /// Slab of queued event payloads.
    store: Vec<Option<E>>,
    /// Recyclable slots of `store`.
    free: Vec<u32>,
    /// FIFO tie-break counter.
    seq: u64,
    /// Virtual time of the most recently popped event.
    now: Micros,
    /// Total events dispatched (popped) — the engine-throughput
    /// numerator reported by `benches/hotpath.rs`.
    dispatched: u64,
}

impl<E> Default for EventCore<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventCore<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            store: Vec::new(),
            free: Vec::new(),
            seq: 0,
            now: 0,
            dispatched: 0,
        }
    }

    /// Current virtual time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Events scheduled but not yet popped.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Total events popped over the core's lifetime.
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Schedule `ev` at time `t` (clamped to `now`).
    pub fn schedule(&mut self, t: Micros, ev: E) {
        self.seq += 1;
        let seq = self.seq;
        self.push_keyed(t, seq, ev);
    }

    /// Schedule `ev` at `(t, seq)` with a caller-assigned sequence
    /// number. This is the sharded-merge entry point: the
    /// [`crate::engine::ShardedDes`] assigns *globally* monotone
    /// sequence numbers at schedule time so the K per-shard heaps can
    /// be merged back into exactly the order a single core would have
    /// produced. The local counter ratchets up to `seq` so a later
    /// plain [`Self::schedule`] can never reuse a smaller number.
    pub fn schedule_with_seq(&mut self, t: Micros, seq: u64, ev: E) {
        self.seq = self.seq.max(seq);
        self.push_keyed(t, seq, ev);
    }

    fn push_keyed(&mut self, t: Micros, seq: u64, ev: E) {
        let slot = match self.free.pop() {
            Some(s) => {
                // Invariant: a slot handed out by the free-list must not
                // alias a live (still-queued) event — that would make two
                // heap keys dispatch the same payload.
                crate::strict_assert!(
                    self.store[s as usize].is_none(),
                    "free-list slot {s} aliases a live event"
                );
                self.store[s as usize] = Some(ev);
                s
            }
            None => {
                self.store.push(Some(ev));
                (self.store.len() - 1) as u32
            }
        };
        self.heap
            .push((Reverse(t.max(self.now)), Reverse(seq), slot));
    }

    /// The `(time, seq)` key of the next event, without popping it.
    /// The sharded merge compares the K shard heads through this.
    #[inline]
    pub fn peek(&self) -> Option<(Micros, u64)> {
        self.heap
            .peek()
            .map(|&(Reverse(t), Reverse(s), _)| (t, s))
    }

    /// Pop the next event if it is due at or before `horizon`,
    /// advancing `now` to its timestamp. Events beyond the horizon stay
    /// queued (the engines' drain windows end the run; they never
    /// consume past-horizon events).
    pub fn pop_until(&mut self, horizon: Micros) -> Option<(Micros, E)> {
        match self.heap.peek() {
            Some(&(Reverse(t), _, _)) if t <= horizon => {}
            _ => return None,
        }
        let (Reverse(t), _, slot) = self.heap.pop().expect("peeked");
        self.now = t;
        self.dispatched += 1;
        let ev = self.store[slot as usize]
            .take()
            .expect("event slot occupied");
        self.free.push(slot);
        Some((t, ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_fifo_order() {
        let mut c: EventCore<u32> = EventCore::new();
        c.schedule(30, 3);
        c.schedule(10, 1);
        c.schedule(10, 2); // same time: FIFO by schedule order
        c.schedule(20, 9);
        let mut seen = Vec::new();
        while let Some((t, e)) = c.pop_until(Micros::MAX) {
            assert_eq!(t, c.now());
            seen.push(e);
        }
        assert_eq!(seen, vec![1, 2, 9, 3]);
    }

    #[test]
    fn horizon_leaves_future_events_queued() {
        let mut c: EventCore<&'static str> = EventCore::new();
        c.schedule(5, "early");
        c.schedule(50, "late");
        assert_eq!(c.pop_until(10).map(|(_, e)| e), Some("early"));
        assert_eq!(c.pop_until(10), None);
        assert_eq!(c.pending(), 1);
        assert_eq!(c.pop_until(100).map(|(_, e)| e), Some("late"));
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut c: EventCore<u8> = EventCore::new();
        c.schedule(100, 1);
        let _ = c.pop_until(Micros::MAX);
        assert_eq!(c.now(), 100);
        c.schedule(10, 2); // in the past: runs at now
        let (t, _) = c.pop_until(Micros::MAX).unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn slots_are_recycled_not_grown() {
        let mut c: EventCore<u64> = EventCore::new();
        for round in 0..100u64 {
            c.schedule(round as Micros, round);
            let _ = c.pop_until(Micros::MAX);
        }
        // One live event at a time: the slab never exceeds one slot.
        assert_eq!(c.store.len(), 1);
        assert_eq!(c.dispatched(), 100);
    }

    #[test]
    fn external_seq_orders_ties_and_ratchets_counter() {
        let mut c: EventCore<u32> = EventCore::new();
        // Caller-assigned seqs scheduled out of order: ties on time
        // break by seq, not by insertion order.
        c.schedule_with_seq(10, 7, 77);
        c.schedule_with_seq(10, 3, 33);
        c.schedule_with_seq(5, 9, 99);
        assert_eq!(c.peek(), Some((5, 9)));
        let mut seen = Vec::new();
        while let Some((_, e)) = c.pop_until(Micros::MAX) {
            seen.push(e);
        }
        assert_eq!(seen, vec![99, 33, 77]);
        // The local counter ratcheted past the largest external seq,
        // so a plain schedule sorts after everything already seen.
        c.schedule_with_seq(20, 50, 1);
        c.schedule(20, 2);
        let mut tail = Vec::new();
        while let Some((_, e)) = c.pop_until(Micros::MAX) {
            tail.push(e);
        }
        assert_eq!(tail, vec![1, 2]);
        assert_eq!(c.peek(), None);
    }

    #[test]
    fn interleaved_load_keeps_order_and_conservation() {
        let mut c: EventCore<usize> = EventCore::new();
        let mut popped = 0usize;
        for wave in 0..50 {
            for k in 0..20 {
                c.schedule((wave * 10 + k % 3) as Micros, wave * 20 + k);
            }
            while c.pop_until((wave * 10 + 1) as Micros).is_some() {
                popped += 1;
            }
        }
        while c.pop_until(Micros::MAX).is_some() {
            popped += 1;
        }
        assert_eq!(popped, 50 * 20);
        assert_eq!(c.pending(), 0);
    }
}
