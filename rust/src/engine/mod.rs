//! Engine substrate shared by the virtual-time execution engines.
//!
//! [`EventCore`] holds the slab-indexed event queue and pop-advance
//! loop that [`crate::coordinator::des`] (single query) and
//! [`crate::service::engine`] (multi query) both instantiate; the
//! engines contribute only their event vocabularies and handlers.

pub mod core;

// `self::` disambiguates from the `core` built-in crate (E0659).
pub use self::core::EventCore;
