//! Engine substrate shared by the virtual-time execution engines.
//!
//! [`EventCore`] holds the slab-indexed event queue and pop-advance
//! loop that [`crate::coordinator::des`] (single query) and
//! [`crate::service::engine`] (multi query) both instantiate; the
//! engines contribute only their event vocabularies and handlers.
//! [`ShardedDes`] splits that queue across K geographic shards with a
//! deterministic `(time, seq, shard)` merge — both engines now run on
//! it (K=1 by default), and cross-shard handoffs are typed
//! [`CrossShardMsg`] envelopes.

pub mod core;
pub mod sharded;

// `self::` disambiguates from the `core` built-in crate (E0659).
pub use self::core::EventCore;
pub use self::sharded::{CrossShardMsg, ShardedDes};
