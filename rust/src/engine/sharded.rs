//! Sharded discrete-event substrate with a deterministic merge.
//!
//! [`ShardedDes`] splits the event queue of a virtual-time engine
//! across K geographic shards (one [`EventCore`] per shard, shards
//! assigned by [`crate::roadnet::partition()`]) and merges the K heads
//! back into a single dispatch stream. The merge is keyed by
//! `(time, seq, shard)` where `seq` is a *globally* monotone sequence
//! number assigned at schedule time — so the merged order is exactly
//! the order a single [`EventCore`] would have produced, and per-seed
//! bit-identity at K=1 plus K-invariance of every downstream result
//! (summaries, detections, ledgers, RNG draws) hold *by construction*.
//! The property suite (`rust/tests/prop_shard.rs`) proves rather than
//! assumes this.
//!
//! Cross-shard handoff: when the event being dispatched lives on shard
//! A and its handler schedules onto shard B, the new event rides a
//! boundary edge of the partition as a typed [`CrossShardMsg`]
//! envelope — [`ShardedDes::schedule`] returns the envelope so the
//! engine can count it and emit a `TraceEvent::CrossShard`. Under
//! `--features strict-invariants` the merge additionally checks three
//! invariants at runtime: emitted keys strictly increase, a popped
//! head matches its peeked key, and (when entity tracking is on) a
//! handed-off entity is owned by exactly one shard at a time.
//!
//! Opt-in parallelism: `threads > 0` runs each shard's [`EventCore`]
//! on its own std thread behind a channel protocol. The merge loop is
//! unchanged — it compares the K cached heads and pops the global
//! minimum — so the threaded path produces bit-identical results to
//! the sequential one (also proven by the property suite), while heap
//! maintenance (sift-up/down, slab bookkeeping) runs off the
//! coordinator thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::core::EventCore;
use crate::util::{FastMap, Micros};

/// Typed envelope for an event handed across a shard boundary: the
/// dispatching shard (`from`), the receiving shard (`to`), the merged
/// virtual time and the global sequence number of the handed-off
/// event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrossShardMsg {
    pub from: u32,
    pub to: u32,
    pub at: Micros,
    pub seq: u64,
}

/// Messages to a shard worker thread (threaded mode only).
enum ToWorker<E> {
    /// Insert an event with its pre-assigned global sequence number.
    Schedule { t: Micros, seq: u64, ev: E },
    /// Pop the shard head if due at or before `horizon`.
    Pop { horizon: Micros },
    Exit,
}

/// Replies from a shard worker thread.
enum FromWorker<E> {
    /// New head key after a `Schedule`.
    Head(Option<(Micros, u64)>),
    /// Result of a `Pop`, plus the new head key.
    Popped {
        popped: Option<(Micros, E)>,
        head: Option<(Micros, u64)>,
    },
}

/// K shard workers, one std thread each. `Schedule` is fire-and-forget
/// (the worker's head reply is drained lazily before the next peek of
/// that shard); `Pop` is synchronous. The protocol keeps the merge
/// decision on the coordinator thread, so ordering is identical to the
/// inline backend by construction.
struct ThreadedShards<E> {
    tx: Vec<Sender<ToWorker<E>>>,
    rx: Vec<Receiver<FromWorker<E>>>,
    /// Last known `(time, seq)` head per shard, refreshed by worker
    /// replies.
    heads: Vec<Option<(Micros, u64)>>,
    /// Outstanding `Schedule` replies not yet drained, per shard.
    pending: Vec<usize>,
    workers: Vec<Option<JoinHandle<()>>>,
}

impl<E> ThreadedShards<E> {
    fn drain(&mut self, s: usize) {
        while self.pending[s] > 0 {
            match self.rx[s].recv().expect("shard worker alive") {
                FromWorker::Head(h) => self.heads[s] = h,
                FromWorker::Popped { .. } => {
                    unreachable!("Pop replies are consumed synchronously")
                }
            }
            self.pending[s] -= 1;
        }
    }
}

impl<E> Drop for ThreadedShards<E> {
    fn drop(&mut self) {
        for tx in &self.tx {
            // A worker that already exited (panicked) has closed its
            // channel; nothing to signal.
            let _ = tx.send(ToWorker::Exit);
        }
        for w in &mut self.workers {
            if let Some(h) = w.take() {
                let _ = h.join();
            }
        }
    }
}

/// Per-shard event storage: K inline cores, or K worker threads.
enum Backend<E> {
    Inline(Vec<EventCore<E>>),
    Threads(ThreadedShards<E>),
}

impl<E> Backend<E> {
    fn schedule(&mut self, s: usize, t: Micros, seq: u64, ev: E) {
        match self {
            Backend::Inline(cores) => {
                cores[s].schedule_with_seq(t, seq, ev);
            }
            Backend::Threads(th) => {
                th.tx[s]
                    .send(ToWorker::Schedule { t, seq, ev })
                    .expect("shard worker alive");
                th.pending[s] += 1;
            }
        }
    }

    fn peek(&mut self, s: usize) -> Option<(Micros, u64)> {
        match self {
            Backend::Inline(cores) => cores[s].peek(),
            Backend::Threads(th) => {
                th.drain(s);
                th.heads[s]
            }
        }
    }

    fn pop(&mut self, s: usize, horizon: Micros) -> Option<(Micros, E)> {
        match self {
            Backend::Inline(cores) => cores[s].pop_until(horizon),
            Backend::Threads(th) => {
                th.drain(s);
                th.tx[s]
                    .send(ToWorker::Pop { horizon })
                    .expect("shard worker alive");
                match th.rx[s].recv().expect("shard worker alive") {
                    FromWorker::Popped { popped, head } => {
                        th.heads[s] = head;
                        popped
                    }
                    FromWorker::Head(_) => {
                        unreachable!("Schedule replies were drained")
                    }
                }
            }
        }
    }
}

/// K per-shard event queues behind the single-core `schedule` /
/// `pop_until` interface, merged deterministically (see the module
/// docs for the contract). At K=1 this is a thin veneer over one
/// [`EventCore`].
pub struct ShardedDes<E> {
    backend: Backend<E>,
    /// Globally monotone schedule-time sequence counter — the merge's
    /// FIFO tie-break, shared by all shards.
    seq: u64,
    /// Merged virtual time (time of the last popped event).
    now: Micros,
    /// Shard of the event currently being dispatched (`None` outside
    /// the pop loop, e.g. during setup). Schedules targeting a
    /// different shard than `current` are cross-shard handoffs.
    current: Option<u32>,
    dispatched: u64,
    per_shard: Vec<u64>,
    cross_shard: u64,
    queued: usize,
    /// Entity-ownership ledger (armed by [`Self::set_entity_tracking`];
    /// the engines arm it under `strict-invariants` at K>1). Entries
    /// are inserted, never removed — acceptable for checking builds.
    owner: FastMap<u64, u32>,
    track_entities: bool,
    /// Last emitted `(time, seq, shard)` merge key.
    last_key: Option<(Micros, u64, u32)>,
}

impl<E> ShardedDes<E> {
    /// K inline (sequential) shards. `shards` is clamped to ≥ 1.
    pub fn new(shards: usize) -> Self {
        let k = shards.max(1);
        Self::with_backend(
            Backend::Inline((0..k).map(|_| EventCore::new()).collect()),
            k,
        )
    }

    fn with_backend(backend: Backend<E>, k: usize) -> Self {
        Self {
            backend,
            seq: 0,
            now: 0,
            current: None,
            dispatched: 0,
            per_shard: vec![0; k],
            cross_shard: 0,
            queued: 0,
            owner: FastMap::default(),
            track_entities: false,
            last_key: None,
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Merged virtual time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Events scheduled but not yet popped, across all shards.
    pub fn pending(&self) -> usize {
        self.queued
    }

    /// Total events popped over the merge's lifetime.
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Events dispatched per shard (index = shard id).
    pub fn per_shard_dispatched(&self) -> &[u64] {
        &self.per_shard
    }

    /// Cross-shard handoffs (envelopes issued) so far.
    pub fn cross_shard_msgs(&self) -> u64 {
        self.cross_shard
    }

    /// Shard of the event currently being dispatched, if any.
    pub fn current_shard(&self) -> Option<u32> {
        self.current
    }

    /// Arm or disarm the entity-ownership ledger. The engines arm it
    /// only when `cfg!(feature = "strict-invariants")` and K > 1, so
    /// production runs never pay for the map.
    pub fn set_entity_tracking(&mut self, on: bool) {
        self.track_entities = on;
    }

    /// Current owning shard of an entity, if tracked.
    pub fn entity_owner(&self, id: u64) -> Option<u32> {
        self.owner.get(&id).copied()
    }

    /// Record a same-shard arrival of entity `id` (no envelope).
    /// Invariant: an already-owned entity cannot silently change
    /// shards without a [`CrossShardMsg`] — the only sanctioned
    /// exception is the coordinator shard (0) seizing orphans during
    /// failure recovery (it re-dispatches from its own copy).
    pub fn note_arrival(&mut self, id: u64, shard: u32) {
        if !self.track_entities {
            return;
        }
        let prev = self.owner.insert(id, shard);
        crate::strict_assert!(
            prev.is_none()
                || prev == Some(shard)
                || self.current == Some(0),
            "entity {id} moved to shard {shard} without a CrossShardMsg \
             envelope (owner was {prev:?})"
        );
    }

    /// Record a cross-shard handoff of entity `id` riding an envelope
    /// `from → to`. Invariant: the handoff originates from the owning
    /// shard (exactly-one-owner), except the shard-0 recovery seize.
    pub fn record_handoff(&mut self, id: u64, from: u32, to: u32) {
        if !self.track_entities {
            return;
        }
        let prev = self.owner.insert(id, to);
        crate::strict_assert!(
            prev.is_none() || prev == Some(from) || from == 0,
            "entity {id} handed off {from} -> {to} but is owned by \
             shard {prev:?}"
        );
    }

    /// Schedule `ev` at time `t` (clamped to merged `now`) on `shard`.
    /// Returns the [`CrossShardMsg`] envelope when this schedule is a
    /// cross-shard handoff — i.e. it happens while dispatching an
    /// event of a *different* shard. Schedules from outside the pop
    /// loop (setup) are local by definition.
    pub fn schedule(
        &mut self,
        t: Micros,
        shard: u32,
        ev: E,
    ) -> Option<CrossShardMsg> {
        // Clamp against the *merged* clock: a shard-local core has
        // only seen times ≤ the merged now, so its inner clamp is a
        // no-op and K=1 behaves bit-identically to a lone EventCore.
        let t = t.max(self.now);
        self.seq += 1;
        let seq = self.seq;
        self.backend.schedule(shard as usize, t, seq, ev);
        self.queued += 1;
        match self.current {
            Some(from) if from != shard => {
                self.cross_shard += 1;
                Some(CrossShardMsg {
                    from,
                    to: shard,
                    at: t,
                    seq,
                })
            }
            _ => None,
        }
    }

    /// Pop the globally next event — the minimum `(time, seq)` over
    /// all shard heads — if due at or before `horizon`. Advances the
    /// merged clock and marks the event's shard as `current` for the
    /// duration of its dispatch.
    pub fn pop_until(&mut self, horizon: Micros) -> Option<(Micros, E)> {
        let mut best: Option<(Micros, u64, usize)> = None;
        for s in 0..self.per_shard.len() {
            if let Some((t, q)) = self.backend.peek(s) {
                let better = match best {
                    None => true,
                    Some((bt, bq, _)) => (t, q) < (bt, bq),
                };
                if better {
                    best = Some((t, q, s));
                }
            }
        }
        let (t, seq, s) = match best {
            Some(b) if b.0 <= horizon => b,
            _ => {
                self.current = None;
                return None;
            }
        };
        let (pt, ev) = self
            .backend
            .pop(s, horizon)
            .expect("peeked shard head within horizon");
        crate::strict_assert!(
            pt == t,
            "shard {s} popped t={pt} but its peeked head was t={t}"
        );
        if let Some((lt, lq, ls)) = self.last_key {
            // The merge-order invariant: emitted keys strictly
            // increase lexicographically (seq is globally unique, so
            // the shard component never tie-breaks).
            crate::strict_assert!(
                (t, seq) > (lt, lq),
                "merge emitted ({t}, {seq}, shard {s}) after \
                 ({lt}, {lq}, shard {ls})"
            );
        }
        self.last_key = Some((t, seq, s as u32));
        self.now = t;
        self.current = Some(s as u32);
        self.dispatched += 1;
        self.per_shard[s] += 1;
        self.queued -= 1;
        Some((t, ev))
    }
}

impl<E: Send + 'static> ShardedDes<E> {
    /// K shards with an opt-in threaded backend: `threads > 0` runs
    /// one worker thread per shard (the count is advisory — shards are
    /// the unit of parallelism); `threads == 0` is the sequential
    /// inline backend. Both produce bit-identical dispatch streams.
    pub fn with_threads(shards: usize, threads: usize) -> Self {
        let k = shards.max(1);
        if threads == 0 {
            return Self::new(k);
        }
        let mut tx = Vec::with_capacity(k);
        let mut rx = Vec::with_capacity(k);
        let mut workers = Vec::with_capacity(k);
        for _ in 0..k {
            let (to_tx, to_rx) = channel::<ToWorker<E>>();
            let (from_tx, from_rx) = channel::<FromWorker<E>>();
            workers.push(Some(std::thread::spawn(move || {
                shard_worker(to_rx, from_tx);
            })));
            tx.push(to_tx);
            rx.push(from_rx);
        }
        Self::with_backend(
            Backend::Threads(ThreadedShards {
                tx,
                rx,
                heads: vec![None; k],
                pending: vec![0; k],
                workers,
            }),
            k,
        )
    }
}

/// Body of a shard worker thread: apply schedule/pop requests to the
/// shard's own [`EventCore`] and report the resulting head key. Send
/// failures mean the coordinator is gone — exit quietly.
fn shard_worker<E>(
    rx: Receiver<ToWorker<E>>,
    tx: Sender<FromWorker<E>>,
) {
    let mut core: EventCore<E> = EventCore::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Schedule { t, seq, ev } => {
                core.schedule_with_seq(t, seq, ev);
                if tx.send(FromWorker::Head(core.peek())).is_err() {
                    return;
                }
            }
            ToWorker::Pop { horizon } => {
                let popped = core.pop_until(horizon);
                let head = core.peek();
                if tx.send(FromWorker::Popped { popped, head }).is_err() {
                    return;
                }
            }
            ToWorker::Exit => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the same schedule stream through a lone EventCore and a
    /// K=1 ShardedDes: pops must be bit-identical.
    #[test]
    fn k1_matches_single_core() {
        let mut solo: EventCore<u32> = EventCore::new();
        let mut sharded: ShardedDes<u32> = ShardedDes::new(1);
        for (t, v) in [(30, 1u32), (10, 2), (10, 3), (20, 4), (5, 5)] {
            solo.schedule(t, v);
            assert_eq!(sharded.schedule(t, 0, v), None);
        }
        loop {
            let a = solo.pop_until(40);
            let b = sharded.pop_until(40);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(solo.dispatched(), sharded.dispatched());
        assert_eq!(sharded.cross_shard_msgs(), 0);
        assert_eq!(sharded.per_shard_dispatched(), &[5]);
    }

    /// The merge emits the global (time, seq) order regardless of
    /// which shard holds each event.
    #[test]
    fn merge_is_globally_time_seq_ordered() {
        let mut d: ShardedDes<usize> = ShardedDes::new(3);
        let plan = [
            (50, 2u32),
            (10, 1),
            (10, 2),
            (30, 0),
            (10, 0),
            (20, 1),
        ];
        for (i, &(t, shard)) in plan.iter().enumerate() {
            d.schedule(t, shard, i);
        }
        let mut popped = Vec::new();
        while let Some((t, i)) = d.pop_until(Micros::MAX) {
            popped.push((t, i));
        }
        // Time-major, schedule-order (seq) within ties — exactly the
        // single-core contract.
        assert_eq!(
            popped,
            vec![(10, 1), (10, 2), (10, 4), (20, 5), (30, 3), (50, 0)]
        );
        assert_eq!(d.pending(), 0);
        assert_eq!(d.dispatched(), 6);
    }

    #[test]
    fn envelopes_issued_exactly_on_cross_shard_schedules() {
        let mut d: ShardedDes<&'static str> = ShardedDes::new(2);
        // Setup (no dispatch context): local by definition.
        assert_eq!(d.schedule(10, 0, "a"), None);
        assert_eq!(d.schedule(20, 1, "b"), None);
        let (_, ev) = d.pop_until(Micros::MAX).unwrap();
        assert_eq!(ev, "a");
        assert_eq!(d.current_shard(), Some(0));
        // Dispatching on shard 0: same-shard schedule has no envelope…
        assert_eq!(d.schedule(15, 0, "c"), None);
        // …a cross-shard one does, stamped with the handoff metadata.
        let msg = d.schedule(18, 1, "d").expect("cross-shard envelope");
        assert_eq!((msg.from, msg.to, msg.at), (0, 1, 18));
        assert_eq!(d.cross_shard_msgs(), 1);
        // Past-time schedule clamped to the merged now (10), not 0.
        assert!(d.schedule(3, 0, "e").is_none());
        let order: Vec<_> =
            std::iter::from_fn(|| d.pop_until(Micros::MAX)).collect();
        assert_eq!(
            order,
            vec![(10, "e"), (15, "c"), (18, "d"), (20, "b")]
        );
        // Outside the pop loop again: no dispatch context.
        assert_eq!(d.current_shard(), None);
        assert_eq!(d.schedule(99, 1, "f"), None);
    }

    /// Same schedule stream through the inline and threaded backends:
    /// identical pops, counters and envelopes.
    #[test]
    fn threaded_backend_matches_inline() {
        let mut a: ShardedDes<u64> = ShardedDes::new(3);
        let mut b: ShardedDes<u64> = ShardedDes::with_threads(3, 3);
        let schedule = |d: &mut ShardedDes<u64>| {
            for i in 0..60u64 {
                let t = ((i * 37) % 50) as Micros;
                let shard = (i % 3) as u32;
                d.schedule(t, shard, i);
            }
        };
        schedule(&mut a);
        schedule(&mut b);
        for horizon in [10, 25, Micros::MAX] {
            loop {
                let (x, y) = (a.pop_until(horizon), b.pop_until(horizon));
                assert_eq!(x, y);
                // Mid-drain schedules exercise the worker protocol's
                // pending/drain path.
                if let Some((t, v)) = x {
                    if v % 7 == 0 {
                        let ma = a.schedule(t + 3, (v % 3) as u32, v + 1000);
                        let mb = b.schedule(t + 3, (v % 3) as u32, v + 1000);
                        assert_eq!(ma, mb);
                    }
                } else {
                    break;
                }
            }
        }
        assert_eq!(a.dispatched(), b.dispatched());
        assert_eq!(a.pending(), b.pending());
        assert_eq!(a.per_shard_dispatched(), b.per_shard_dispatched());
        assert_eq!(a.cross_shard_msgs(), b.cross_shard_msgs());
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn entity_ownership_tracks_handoffs() {
        let mut d: ShardedDes<u8> = ShardedDes::new(4);
        d.set_entity_tracking(true);
        d.note_arrival(7, 1);
        assert_eq!(d.entity_owner(7), Some(1));
        d.record_handoff(7, 1, 3);
        assert_eq!(d.entity_owner(7), Some(3));
        // The coordinator-shard recovery seize is sanctioned even when
        // shard 0 never owned the entity.
        d.record_handoff(7, 0, 2);
        assert_eq!(d.entity_owner(7), Some(2));
        // Untracked instances keep the map empty.
        let mut off: ShardedDes<u8> = ShardedDes::new(4);
        off.note_arrival(7, 1);
        assert_eq!(off.entity_owner(7), None);
    }

    /// The exactly-one-owner invariant actually fires when armed: a
    /// handoff claiming the wrong source shard panics.
    #[cfg(feature = "strict-invariants")]
    #[test]
    #[should_panic(expected = "handed off")]
    fn wrong_owner_handoff_panics_under_strict_invariants() {
        let mut d: ShardedDes<u8> = ShardedDes::new(4);
        d.set_entity_tracking(true);
        d.note_arrival(7, 1);
        d.record_handoff(7, 2, 3); // owner is shard 1, not 2
    }
}
