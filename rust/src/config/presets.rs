//! Named experiment presets — one per panel of the paper's evaluation.
//!
//! `preset("fig7a")` etc. return ready-to-run [`ExperimentConfig`]s; the
//! harness binary iterates these to regenerate every figure/table.

use super::*;

/// All preset names, in paper order.
pub const ALL: &[&str] = &[
    "fig7a", "fig7b", "fig7c", "fig7d", "fig6b_sb1", "fig6b_sb20",
    "fig6b_db25", "fig9_anv", "fig9_nob", "fig9_compute_frozen",
    "fig9_compute_online", "fig10_wbfs_sb1", "fig10_base_100",
    "fig10_base_200", "fig11_nodrops", "fig11_drops", "fig12_sb20",
    "fig12_db25", "fig12_wbfs_sb20", "fig12_es6_db25",
    "fig12_es6_drops", "faults_recovery_on", "faults_recovery_off",
    "adapt_on", "adapt_off",
];

/// The non-native rungs of the adaptation A/B ladder ("harness adapt").
/// Strides stay 1 so both arms offer identical load — the controller
/// trades per-event cost/accuracy, never event count.
fn adapt_ladder() -> Vec<ResolutionLevel> {
    vec![
        ResolutionLevel::native(),
        ResolutionLevel {
            scale: 0.5,
            cost: 0.55,
            accuracy: 0.97,
            stride: 1,
        },
        ResolutionLevel {
            scale: 0.25,
            cost: 0.35,
            accuracy: 0.92,
            stride: 1,
        },
    ]
}

/// Build the named preset. Panics on unknown names (the harness validates
/// against [`ALL`]).
pub fn preset(name: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = name.to_string();
    match name {
        // ---- Fig 5a / 6a / 7: App 1 batching knob, TL-BFS, es = 4 ----
        "fig7a" => {
            c.batching = BatchingKind::Static { size: 1 };
        }
        "fig7b" => {
            c.batching = BatchingKind::Static { size: 20 };
        }
        "fig7c" => {
            c.batching = BatchingKind::Nob { max: 25 };
        }
        "fig7d" => {
            c.batching = BatchingKind::Dynamic { max: 25 };
        }
        // ---- Fig 6b: es = 6 m/s ----
        "fig6b_sb1" => {
            c.tl_peak_speed_mps = 6.0;
            c.batching = BatchingKind::Static { size: 1 };
        }
        "fig6b_sb20" => {
            c.tl_peak_speed_mps = 6.0;
            c.batching = BatchingKind::Static { size: 20 };
        }
        "fig6b_db25" => {
            c.tl_peak_speed_mps = 6.0;
            c.batching = BatchingKind::Dynamic { max: 25 };
        }
        // ---- Fig 9: bandwidth 1 Gbps -> 30 Mbps at t = 300 s ----
        "fig9_anv" | "fig9_nob" => {
            c.batching = if name == "fig9_anv" {
                BatchingKind::Dynamic { max: 25 }
            } else {
                BatchingKind::Nob { max: 25 }
            };
            c.network.events.push(BandwidthEvent {
                at_sec: 300.0,
                bandwidth_bps: 30e6,
            });
        }
        // ---- Compute dynamism (Fig 9-style, compute edition): every
        // compute node slows 4x at t = 300 s; frozen vs online ξ ----
        "fig9_compute_frozen" | "fig9_compute_online" => {
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.drops_enabled = true;
            c.service.online_xi = name.ends_with("online");
            c.service.compute_events.push(ComputeEvent {
                at_sec: 300.0,
                node: None,
                factor: 4.0,
            });
        }
        // ---- Fig 10: tracking-logic knob ----
        "fig10_wbfs_sb1" => {
            c.tl = TlKind::Wbfs;
            c.batching = BatchingKind::Static { size: 1 };
        }
        "fig10_base_100" => {
            c.tl = TlKind::Base;
            c.num_cameras = 100;
            c.workload.vertices = 100;
            c.workload.edges = 282;
            c.batching = BatchingKind::Static { size: 20 };
        }
        "fig10_base_200" => {
            c.tl = TlKind::Base;
            c.num_cameras = 200;
            c.workload.vertices = 200;
            c.workload.edges = 563;
            c.batching = BatchingKind::Static { size: 20 };
        }
        // ---- Fig 11: drop knob at es = 7 m/s ----
        "fig11_nodrops" | "fig11_drops" => {
            c.tl_peak_speed_mps = 7.0;
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.drops_enabled = name == "fig11_drops";
        }
        // ---- Robustness A/B ("harness faults"): node 1 crashes for
        // good at t = 300 s; the only difference between the pair is
        // the recovery switch. Base TL at 200 cameras keeps the whole
        // network active, so the offered load is identical across the
        // arms and the on-time comparison is apples to apples. ----
        "faults_recovery_on" | "faults_recovery_off" => {
            c.tl = TlKind::Base;
            c.num_cameras = 200;
            c.workload.vertices = 200;
            c.workload.edges = 563;
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.service.fault_events.push(FaultEvent {
                at_sec: 300.0,
                kind: FaultKind::NodeCrash {
                    node: 1,
                    down_secs: None,
                },
            });
            c.service.recovery.enabled = name.ends_with("_on");
        }
        // ---- Adaptation A/B ("harness adapt"): every compute node
        // slows 4x at t = 300 s; the only difference between the pair
        // is the controller switch — both arms carry the same ladder,
        // so the off arm is the frozen baseline under identical load
        // (Base TL at 200 cameras, stride-1 ladder). ----
        "adapt_on" | "adapt_off" => {
            c.tl = TlKind::Base;
            c.num_cameras = 200;
            c.workload.vertices = 200;
            c.workload.edges = 563;
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.drops_enabled = true;
            c.service.compute_events.push(ComputeEvent {
                at_sec: 300.0,
                node: None,
                factor: 4.0,
            });
            c.adaptation.ladder = adapt_ladder();
            c.adaptation.enabled = name.ends_with("_on");
        }
        // ---- Fig 12: App 2 (large CR) ----
        "fig12_sb20" => {
            c.app = AppKind::App2;
            c.batching = BatchingKind::Static { size: 20 };
        }
        "fig12_db25" => {
            c.app = AppKind::App2;
            c.batching = BatchingKind::Dynamic { max: 25 };
        }
        "fig12_wbfs_sb20" => {
            c.app = AppKind::App2;
            c.tl = TlKind::Wbfs;
            c.batching = BatchingKind::Static { size: 20 };
        }
        "fig12_es6_db25" => {
            c.app = AppKind::App2;
            c.tl_peak_speed_mps = 6.0;
            c.batching = BatchingKind::Dynamic { max: 25 };
        }
        "fig12_es6_drops" => {
            c.app = AppKind::App2;
            c.tl_peak_speed_mps = 6.0;
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.drops_enabled = true;
        }
        other => panic!("unknown preset {other:?}"),
    }
    if matches!(c.app, AppKind::App2) {
        // App 2's CR DNN takes ~63% longer per frame (§5.3).
        c.service.cr_alpha_ms *= 1.63;
        c.service.cr_beta_ms *= 1.63;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_build() {
        for name in ALL {
            let c = preset(name);
            assert_eq!(&c.name, name);
        }
    }

    #[test]
    fn fig9_has_bandwidth_event() {
        let c = preset("fig9_anv");
        assert_eq!(c.network.events.len(), 1);
        assert!((c.network.events[0].at_sec - 300.0).abs() < 1e-9);
        assert!((c.network.events[0].bandwidth_bps - 30e6).abs() < 1.0);
    }

    #[test]
    fn fig12_cr_is_slower() {
        let a1 = preset("fig7d");
        let a2 = preset("fig12_db25");
        let x1 = a1.service.cr_alpha_ms + a1.service.cr_beta_ms;
        let x2 = a2.service.cr_alpha_ms + a2.service.cr_beta_ms;
        assert!((x2 / x1 - 1.63).abs() < 0.01);
    }

    #[test]
    fn compute_presets_differ_only_in_online_xi() {
        let f = preset("fig9_compute_frozen");
        let o = preset("fig9_compute_online");
        for c in [&f, &o] {
            assert_eq!(c.service.compute_events.len(), 1);
            assert_eq!(c.service.compute_events[0].node, None);
            assert!((c.service.compute_events[0].factor - 4.0).abs() < 1e-9);
            assert!((c.service.compute_events[0].at_sec - 300.0).abs() < 1e-9);
            assert!(c.drops_enabled);
        }
        assert!(!f.service.online_xi);
        assert!(o.service.online_xi);
    }

    #[test]
    fn base_presets_shrink_network() {
        let c = preset("fig10_base_100");
        assert_eq!(c.num_cameras, 100);
        assert_eq!(c.workload.vertices, 100);
        assert!(matches!(c.tl, TlKind::Base));
    }

    #[test]
    fn fault_presets_are_an_ab_pair() {
        let on = preset("faults_recovery_on");
        let off = preset("faults_recovery_off");
        for c in [&on, &off] {
            assert_eq!(c.service.fault_events.len(), 1);
            assert!((c.service.fault_events[0].at_sec - 300.0).abs()
                < 1e-9);
            assert!(matches!(
                c.service.fault_events[0].kind,
                FaultKind::NodeCrash { node: 1, down_secs: None }
            ));
            assert!(matches!(c.tl, TlKind::Base));
        }
        assert!(on.service.recovery.enabled);
        assert!(!off.service.recovery.enabled);
    }

    #[test]
    fn adapt_presets_are_an_ab_pair() {
        let on = preset("adapt_on");
        let off = preset("adapt_off");
        for c in [&on, &off] {
            assert_eq!(c.adaptation.ladder.len(), 3);
            assert!(c.adaptation.ladder[0].is_native());
            // Equal offered load across the arms: no stride rungs.
            assert!(c.adaptation.ladder.iter().all(|l| l.stride == 1));
            assert_eq!(c.service.compute_events.len(), 1);
            assert!((c.service.compute_events[0].factor - 4.0).abs()
                < 1e-9);
            assert!(matches!(c.tl, TlKind::Base));
            assert!(c.drops_enabled);
        }
        assert!(on.adaptation.enabled);
        assert!(!off.adaptation.enabled);
        assert!(!off.adaptation.is_identity() || !off.adaptation.enabled);
    }

    #[test]
    #[should_panic(expected = "unknown preset")]
    fn unknown_preset_panics() {
        preset("nope");
    }
}
