//! Experiment and application configuration.
//!
//! Every paper experiment is expressible as an [`ExperimentConfig`]; the
//! harness ships named presets (one per figure panel) and any config can
//! be loaded from / saved to TOML for the launcher CLI.

pub mod io;
mod presets;

pub use presets::{preset, ALL as PRESETS};

use crate::util::{millis, secs, Micros};

/// Which tracking application (Table 1) to compose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    /// HoG-like VA + small re-id CR, WBFS TL.
    App1,
    /// App 1 with the larger (≈63% slower) CR DNN and query fusion.
    App2,
    /// Vehicle variant: frame-rate FC control, speed-aware WBFS.
    App3,
    /// Two-stage re-id with probabilistic TL.
    App4,
}

impl AppKind {
    /// Dense index (0..4) — used wherever per-app state lives in a
    /// fixed array (the `AppCatalog`, the multi-query engine's per-app
    /// ξ multipliers).
    pub fn index(self) -> usize {
        match self {
            AppKind::App1 => 0,
            AppKind::App2 => 1,
            AppKind::App3 => 2,
            AppKind::App4 => 3,
        }
    }
}

/// Tracking-Logic strategy (the "scalability" knob of the tuning triangle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TlKind {
    /// Keep every camera active all the time (contemporary baseline).
    Base,
    /// Spotlight BFS with a fixed assumed road length.
    Bfs,
    /// Weighted BFS (Dijkstra ball) with exact road lengths.
    Wbfs,
    /// WBFS that also adapts the radius to the entity's observed speed.
    WbfsSpeed,
    /// Naive-Bayes path-likelihood activation (App 4).
    Probabilistic,
}

/// Batching strategy (the "latency" knob).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchingKind {
    /// Fixed batch size; submits only when full (paper's SB-b).
    Static { size: usize },
    /// Anveshak's budget/deadline-driven dynamic batching (DB-bmax).
    Dynamic { max: usize },
    /// Near-Optimal Baseline: rate -> batch-size lookup table (§5.1).
    Nob { max: usize },
}

impl BatchingKind {
    pub fn label(&self) -> String {
        match self {
            BatchingKind::Static { size } => format!("SB-{size}"),
            BatchingKind::Dynamic { max } => format!("DB-{max}"),
            BatchingKind::Nob { max } => format!("NOB-{max}"),
        }
    }
}

/// Cluster topology: mirrors the paper's 1 head + 10 compute nodes, each
/// compute node hosting FC/VA/CR executors on Pi-3B-class cores.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub compute_nodes: usize,
    pub va_instances: usize,
    pub cr_instances: usize,
    /// Per-device clock skew bound (± ms) for non-source/sink devices.
    pub clock_skew_ms: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            compute_nodes: 10,
            va_instances: 10,
            cr_instances: 10,
            clock_skew_ms: 0.0,
        }
    }
}

/// A scheduled change to the inter-node bandwidth (Fig 9's 1 Gbps ->
/// 30 Mbps drop at t = 300 s).
#[derive(Debug, Clone, Copy)]
pub struct BandwidthEvent {
    pub at_sec: f64,
    pub bandwidth_bps: f64,
}

/// A scheduled change to a node's compute speed — the compute half of
/// the §6 dynamism story, mirroring [`BandwidthEvent`]. From `at_sec`
/// onward, batches executing on `node` take `factor` times their
/// nominal duration (4.0 = a 4x slowdown; 1.0 restores full speed).
/// `node: None` applies the step to every cluster node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeEvent {
    pub at_sec: f64,
    /// Target node index, or `None` for all nodes.
    pub node: Option<usize>,
    pub factor: f64,
}

/// What kind of failure a [`FaultEvent`] injects. Each is the limiting
/// case of the PR 5 dynamism machinery — a resource whose
/// compute/bandwidth factor has gone to ∞ — so pricing, ξ and the drop
/// gates compose with it unchanged; the engines model the limit as
/// aliveness checks plus recovery machinery instead of a literal
/// infinite duration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A cluster node crashes. `down_secs: None` is a permanent crash;
    /// `Some(d)` restarts the node after a `d`-second downtime window.
    NodeCrash { node: usize, down_secs: Option<f64> },
    /// A camera goes dark (stops producing frames). `down_secs: None`
    /// is permanent; `Some(d)` is a dropout/flap that heals after `d`
    /// seconds.
    CameraOutage { camera: usize, down_secs: Option<f64> },
    /// The inter-node link between nodes `a` and `b` partitions
    /// (bidirectionally). `down_secs: None` is permanent; `Some(d)`
    /// heals after `d` seconds.
    LinkPartition { a: usize, b: usize, down_secs: Option<f64> },
    /// Every inter-node message is independently lost with probability
    /// `prob` while the window is open. `dur_secs: None` keeps the
    /// lossy regime for the rest of the run.
    MessageLoss { prob: f64, dur_secs: Option<f64> },
}

/// A scheduled fault injection, mirroring [`ComputeEvent`] /
/// [`BandwidthEvent`]: from `at_sec` onward the fault in `kind` is in
/// effect (until its own downtime window closes, if any). Schedules are
/// data, not randomness: the same `fault_events` under the same seed
/// produce bit-identical runs, and an empty schedule is guaranteed to
/// leave the engines bit-identical to a build without the fault
/// machinery at all (property-tested).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_sec: f64,
    pub kind: FaultKind,
}

/// Recovery policy applied when [`FaultEvent`]s fire. With `enabled:
/// false` the platform takes every fault at face value (in-flight work
/// on a dead node is lost, partitioned messages vanish) — the A/B
/// baseline for `harness faults`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    /// Master switch for all recovery machinery (retry, re-dispatch,
    /// TL degradation). Faults still fire when false.
    pub enabled: bool,
    /// Bounded retry count for in-flight batches on a dead node and
    /// for lost/partitioned messages.
    pub max_retries: u32,
    /// Base of the exponential backoff between retries (attempt k
    /// waits `backoff_base_ms * 2^k`).
    pub backoff_base_ms: f64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            max_retries: 3,
            backoff_base_ms: 250.0,
        }
    }
}

/// MAN/WAN model between cluster nodes.
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    pub bandwidth_bps: f64,
    pub latency_ms: f64,
    /// Median frame payload size (paper: 2.9 kB CUHK03 JPGs).
    pub frame_bytes: usize,
    /// VA -> CR candidate payload (cropped raw regions for the DNN).
    pub candidate_bytes: usize,
    /// Metadata event size (detections, signals).
    pub meta_bytes: usize,
    /// Model the MAN as one shared backbone serializer (true) or as
    /// independent per-node NICs (false). The paper's Fig 9 bandwidth
    /// drop throttles the fabric between compute nodes.
    pub shared_fabric: bool,
    pub events: Vec<BandwidthEvent>,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        Self {
            bandwidth_bps: 1e9,
            latency_ms: 0.5,
            frame_bytes: 2_900,
            candidate_bytes: 24_000,
            meta_bytes: 256,
            shared_fabric: true,
            events: vec![],
        }
    }
}

/// Per-module service-time model `xi(b) = alpha + beta * b` (ms), i.e.
/// invocation overhead plus per-event marginal cost. Calibrated so CR
/// matches the paper's measured 120 ms/frame at b=1 and xi(25) = 1.74 s.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub fc_ms: f64,
    pub va_alpha_ms: f64,
    pub va_beta_ms: f64,
    pub cr_alpha_ms: f64,
    pub cr_beta_ms: f64,
    pub tl_ms: f64,
    /// Multiplicative jitter bound on actual vs estimated execution time.
    pub jitter: f64,
    /// Scheduled per-node compute slowdowns (the Fig 9-style dynamism
    /// scenario, compute edition) — see [`crate::sim::ComputeModel`].
    pub compute_events: Vec<ComputeEvent>,
    /// Close the ξ calibration loop online: DES executors feed observed
    /// (slowdown-scaled) batch durations into [`XiModel::observe`]
    /// (EMA), so deadline math, NOB lookups and drop gates track the
    /// *current* machine instead of the config-time benchmark — the
    /// same loop the live engine always runs. `false` keeps the frozen
    /// config-time ξ as the comparison baseline.
    ///
    /// [`XiModel::observe`]: crate::tuning::XiModel::observe
    pub online_xi: bool,
    /// Scheduled fault injections (node crashes, camera dropouts,
    /// link partitions, message loss) — see [`crate::sim::FaultModel`].
    /// Empty = the failure-free contract: bit-identical per seed to a
    /// build without the fault machinery.
    pub fault_events: Vec<FaultEvent>,
    /// Recovery policy when `fault_events` fire (ignored when empty).
    pub recovery: RecoveryConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            fc_ms: 0.2,
            va_alpha_ms: 20.0,
            va_beta_ms: 12.0,
            // xi(1) = 120 ms, xi(25) = 1.7475 s — the paper's CR numbers.
            cr_alpha_ms: 52.5,
            cr_beta_ms: 67.5,
            tl_ms: 1.0,
            jitter: 0.05,
            compute_events: vec![],
            online_xi: false,
            fault_events: vec![],
            recovery: RecoveryConfig::default(),
        }
    }
}

/// Detection semantics for the simulated analytics (ground-truth driven;
/// the live engine uses the real PJRT models instead).
#[derive(Debug, Clone)]
pub struct SemanticsConfig {
    /// P(VA flags a frame | entity in frame).
    pub va_tp: f64,
    /// P(VA flags a frame | entity absent) — false positives go to CR.
    pub va_fp: f64,
    /// P(CR confirms | entity in frame and VA flagged).
    pub cr_tp: f64,
    /// P(CR confirms | entity absent).
    pub cr_fp: f64,
    /// P(an entire FOV transit goes undetected) — real re-id misses
    /// whole tracks (occlusion, pose), which is what produces the
    /// paper's long blind-spot spells and 100+ camera spotlights.
    pub transit_miss: f64,
    /// How much a QF refinement sharpens the simulated analytics once
    /// the feedback edge has delivered a fused embedding (§2.2,
    /// Fig. 2): for a refined query the residual error rates shrink by
    /// this fraction — `cr_tp ← cr_tp + boost·(1 − cr_tp)`,
    /// `cr_fp ← cr_fp·(1 − boost)`,
    /// `transit_miss ← transit_miss·(1 − boost)`. 0 disables the
    /// effect; non-fusing apps are unaffected either way (no
    /// refinement is ever applied).
    pub fusion_boost: f64,
}

impl Default for SemanticsConfig {
    fn default() -> Self {
        Self {
            va_tp: 0.98,
            va_fp: 0.02,
            cr_tp: 0.99,
            cr_fp: 0.0,
            transit_miss: 0.05,
            fusion_boost: 0.5,
        }
    }
}

/// Road network + workload generation parameters (§5.1 Workload).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Number of road-graph vertices (paper: 1,000).
    pub vertices: usize,
    /// Target number of edges (paper: 2,817).
    pub edges: usize,
    /// Mean road segment length in metres (paper: 84.5 m).
    pub mean_road_m: f64,
    /// Camera field-of-view radius (metres).
    pub fov_m: f64,
    /// True walking speed of the entity (paper: 1 m/s).
    pub entity_speed_mps: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            vertices: 1000,
            edges: 2817,
            mean_road_m: 84.5,
            // Small FOV relative to road length: the entity spends most
            // of each segment in a blind spot, producing the paper's
            // saw-tooth spotlight growth (peaks >100 cameras).
            fov_m: 10.0,
            entity_speed_mps: 1.0,
        }
    }
}

/// Multi-query service parameters: how many concurrent tracking
/// queries arrive, how they arrive, and the admission-control limits
/// protecting the shared VA/CR workers (see [`crate::service`]).
#[derive(Debug, Clone)]
pub struct MultiQueryConfig {
    /// Total queries submitted over the run.
    pub num_queries: usize,
    /// Mean gap of the Poisson arrival process (seconds).
    pub mean_interarrival_secs: f64,
    /// Tracking window of each query once activated (seconds).
    pub lifetime_secs: f64,
    /// Admission: maximum concurrently active queries.
    pub max_active: usize,
    /// Admission: maximum aggregate active-camera set across queries.
    pub max_active_cameras: usize,
    /// Admission: capacity of the wait queue before outright rejection.
    pub queue_capacity: usize,
    /// Priorities cycle `1..=priority_levels` across arriving queries;
    /// the fair-share scheduler weights batch slots by priority.
    pub priority_levels: u8,
}

impl Default for MultiQueryConfig {
    fn default() -> Self {
        Self {
            num_queries: 8,
            mean_interarrival_secs: 20.0,
            lifetime_secs: 240.0,
            max_active: 16,
            max_active_cameras: 4_000,
            queue_capacity: 8,
            priority_levels: 3,
        }
    }
}

/// Observability knobs (see [`crate::obs`]). These configure the
/// *recording* sinks only — the default `NullSink` path ignores them
/// entirely, which is what keeps the determinism contract trivial.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Flight-recorder capacity for [`crate::obs::RingSink`]. Must be
    /// prime (the `BudgetManager` ring lesson).
    pub ring_capacity: usize,
    /// Dump cumulative [`crate::obs::MetricsRegistry`] rows once per
    /// simulated second in the DES engines (alongside `Timeline`).
    pub per_second_metrics: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self { ring_capacity: 4093, per_second_metrics: true }
    }
}

/// Sharded-DES execution parameters (see `docs/ARCHITECTURE.md`,
/// "Sharded execution"). The determinism contract makes these knobs
/// result-neutral: any `(shards, threads)` pair produces bit-identical
/// summaries/ledgers for the same seed — only the execution geometry
/// (and the cross-shard traffic reported by `obs`) changes.
#[derive(Debug, Clone)]
pub struct ShardingConfig {
    /// Geographic shard count K (clamped to the vertex count; 1 =
    /// single-shard, the pre-sharding engine behaviour).
    pub shards: usize,
    /// Opt-in parallelism: > 0 runs each shard's event core on its
    /// own std thread (the value is advisory — shards are the unit of
    /// parallelism); 0 keeps the sequential inline backend.
    pub threads: usize,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        Self { shards: 1, threads: 0 }
    }
}

/// One rung of a per-camera resolution ladder (the adaptation plane's
/// quality operating points — see [`crate::tuning::adapt`]). Rung 0 is
/// the native quality; deeper rungs trade accuracy for cost, DeepScale
/// style. The **identity ladder** is a single native rung: every
/// multiplier is exactly `1.0`, so an adaptation-aware build prices,
/// scores and transfers bit-identically to a build without the plane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolutionLevel {
    /// Frame-size (byte) multiplier at this rung (1.0 = native).
    pub scale: f64,
    /// ξ cost multiplier an event at this rung contributes to a batch.
    pub cost: f64,
    /// Multiplier on the simulated true-positive rates (≤ 1.0).
    pub accuracy: f64,
    /// Frame stride at this rung (1 = every frame; > 1 decimates the
    /// camera's effective frame rate platform-side).
    pub stride: u64,
}

impl ResolutionLevel {
    /// The native (identity) rung.
    pub fn native() -> Self {
        Self { scale: 1.0, cost: 1.0, accuracy: 1.0, stride: 1 }
    }

    /// Whether this rung is an exact identity.
    pub fn is_native(&self) -> bool {
        self.scale == 1.0
            && self.cost == 1.0
            && self.accuracy == 1.0
            && self.stride <= 1
    }
}

impl Default for ResolutionLevel {
    fn default() -> Self {
        Self::native()
    }
}

/// Adaptation-plane configuration: the per-camera resolution ladder
/// plus the sink-side controller's policy knobs. The default is the
/// identity ladder with the controller off — bit-identical to a build
/// without the adaptation plane, per seed, by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptationConfig {
    /// Master switch for the sink-side controller. Even when `true`,
    /// a single-rung ladder leaves the controller inert.
    pub enabled: bool,
    /// Ordered quality rungs, index 0 = native. Never empty.
    pub ladder: Vec<ResolutionLevel>,
    /// Downshift when deadline slack `(γ − ema)/γ` falls below this.
    pub slack_down: f64,
    /// Upshift when slack recovers above this (must exceed
    /// `slack_down` — the hysteresis band).
    pub slack_up: f64,
    /// Minimum seconds between commands for one camera.
    pub cooldown_secs: f64,
}

impl AdaptationConfig {
    /// Is this the do-nothing configuration (identity ladder)?
    pub fn is_identity(&self) -> bool {
        !self.enabled
            || (self.ladder.len() <= 1
                && self.ladder.iter().all(|l| l.is_native()))
    }
}

impl Default for AdaptationConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ladder: vec![ResolutionLevel::native()],
            slack_down: 0.25,
            slack_up: 0.6,
            cooldown_secs: 5.0,
        }
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Simulated duration (paper timelines run ~600 s).
    pub duration_secs: f64,
    pub num_cameras: usize,
    /// Camera frame rate (paper: 1 fps).
    pub fps: f64,
    /// Maximum tolerable latency gamma (paper: 15 s).
    pub gamma_ms: f64,
    /// TL's configured peak entity speed `es` (m/s): 4, 6 or 7 in §5.
    pub tl_peak_speed_mps: f64,
    pub app: AppKind,
    pub tl: TlKind,
    pub batching: BatchingKind,
    pub drops_enabled: bool,
    /// Seed TL with the entity's last-seen location at t=0 (Fig 1's
    /// narrative: "only CA is made active"). When false, every FC
    /// bootstraps active (§2.3) — which transiently floods the cluster
    /// at 1000 cameras.
    pub seed_last_seen: bool,
    /// Early-arrival threshold epsilon_max for budget increases (§4.5.2).
    pub eps_max_ms: f64,
    /// Send a probe for every k-th dropped event (§4.5.2).
    pub probe_every: u64,
    pub cluster: ClusterConfig,
    pub network: NetworkConfig,
    pub service: ServiceConfig,
    pub semantics: SemanticsConfig,
    pub workload: WorkloadConfig,
    /// Multi-query service parameters (used by the `service` layer and
    /// the engines' multi-query modes; ignored by single-query runs).
    pub multi_query: MultiQueryConfig,
    /// Observability knobs (recording sinks only).
    pub obs: ObsConfig,
    /// Sharded-DES execution geometry (result-neutral by contract).
    pub sharding: ShardingConfig,
    /// Adaptation plane: resolution ladder + controller policy
    /// (identity + disabled by default — see [`crate::tuning::adapt`]).
    pub adaptation: AdaptationConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            seed: 2019,
            duration_secs: 600.0,
            num_cameras: 1000,
            fps: 1.0,
            gamma_ms: 15_000.0,
            tl_peak_speed_mps: 4.0,
            app: AppKind::App1,
            tl: TlKind::Bfs,
            batching: BatchingKind::Dynamic { max: 25 },
            drops_enabled: false,
            seed_last_seen: true,
            eps_max_ms: 2_000.0,
            probe_every: 50,
            cluster: ClusterConfig::default(),
            network: NetworkConfig::default(),
            service: ServiceConfig::default(),
            semantics: SemanticsConfig::default(),
            workload: WorkloadConfig::default(),
            multi_query: MultiQueryConfig::default(),
            obs: ObsConfig::default(),
            sharding: ShardingConfig::default(),
            adaptation: AdaptationConfig::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn gamma(&self) -> Micros {
        millis(self.gamma_ms)
    }

    pub fn duration(&self) -> Micros {
        secs(self.duration_secs)
    }

    /// Load from a JSON file (see [`io`] for the schema).
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        io::config_from_json(&text).map_err(|e| anyhow::anyhow!(e))
    }

    /// Save to a JSON file.
    pub fn to_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, io::config_to_json(self).to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = ExperimentConfig::default();
        assert_eq!(c.num_cameras, 1000);
        assert_eq!(c.gamma(), 15 * crate::util::SEC);
        assert_eq!(c.workload.vertices, 1000);
        assert_eq!(c.workload.edges, 2817);
        assert_eq!(c.cluster.va_instances, 10);
        assert_eq!(c.cluster.cr_instances, 10);
    }

    #[test]
    fn cr_service_matches_paper_calibration() {
        let s = ServiceConfig::default();
        // xi(1) = 120 ms/event => mu = 8.33 events/s per CR instance.
        assert!((s.cr_alpha_ms + s.cr_beta_ms - 120.0).abs() < 1e-9);
        // xi(25) ~ 1.74 s (paper's §5.2.1 budget arithmetic).
        let xi25 = s.cr_alpha_ms + 25.0 * s.cr_beta_ms;
        assert!((xi25 - 1740.0).abs() < 20.0, "xi(25) = {xi25}");
    }

    #[test]
    fn json_round_trip() {
        let mut c = preset("fig9_anv");
        c.drops_enabled = true;
        let j = io::config_to_json(&c).to_string();
        let c2 = io::config_from_json(&j).unwrap();
        assert_eq!(c2.num_cameras, c.num_cameras);
        assert_eq!(c2.batching.label(), c.batching.label());
        assert_eq!(c2.name, c.name);
        assert!(c2.drops_enabled);
        assert_eq!(c2.network.events.len(), 1);
        assert_eq!(c2.app, c.app);
        assert_eq!(c2.tl, c.tl);
    }

    #[test]
    fn adaptation_defaults_to_the_identity_ladder() {
        let c = ExperimentConfig::default();
        assert!(c.adaptation.is_identity());
        assert_eq!(c.adaptation.ladder.len(), 1);
        assert!(c.adaptation.ladder[0].is_native());
        assert!(!c.adaptation.enabled);
        assert!(c.adaptation.slack_up > c.adaptation.slack_down);
        // Enabled with a single native rung is still the identity.
        let mut on = c.adaptation.clone();
        on.enabled = true;
        assert!(on.is_identity());
        // A second rung under `enabled` is not.
        on.ladder.push(ResolutionLevel {
            scale: 0.5,
            cost: 0.5,
            accuracy: 0.95,
            stride: 1,
        });
        assert!(!on.is_identity());
    }

    #[test]
    fn batching_labels() {
        assert_eq!(BatchingKind::Static { size: 20 }.label(), "SB-20");
        assert_eq!(BatchingKind::Dynamic { max: 25 }.label(), "DB-25");
        assert_eq!(BatchingKind::Nob { max: 25 }.label(), "NOB-25");
    }
}
