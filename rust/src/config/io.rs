//! JSON (de)serialization for [`ExperimentConfig`] — the launcher's
//! config-file format (hand-rolled; the offline environment has no
//! serde facade).

use super::*;
use crate::util::json::{obj, Json};

pub fn config_to_json(c: &ExperimentConfig) -> Json {
    obj([
        ("name", c.name.as_str().into()),
        ("seed", (c.seed as i64).into()),
        ("duration_secs", c.duration_secs.into()),
        ("num_cameras", c.num_cameras.into()),
        ("fps", c.fps.into()),
        ("gamma_ms", c.gamma_ms.into()),
        ("tl_peak_speed_mps", c.tl_peak_speed_mps.into()),
        ("app", app_str(c.app).into()),
        ("tl", tl_str(c.tl).into()),
        ("batching", batching_to_json(&c.batching)),
        ("drops_enabled", c.drops_enabled.into()),
        ("seed_last_seen", c.seed_last_seen.into()),
        ("eps_max_ms", c.eps_max_ms.into()),
        ("probe_every", (c.probe_every as i64).into()),
        (
            "cluster",
            obj([
                ("compute_nodes", c.cluster.compute_nodes.into()),
                ("va_instances", c.cluster.va_instances.into()),
                ("cr_instances", c.cluster.cr_instances.into()),
                ("clock_skew_ms", c.cluster.clock_skew_ms.into()),
            ]),
        ),
        (
            "network",
            obj([
                ("bandwidth_bps", c.network.bandwidth_bps.into()),
                ("latency_ms", c.network.latency_ms.into()),
                ("frame_bytes", c.network.frame_bytes.into()),
                ("candidate_bytes", c.network.candidate_bytes.into()),
                ("meta_bytes", c.network.meta_bytes.into()),
                ("shared_fabric", c.network.shared_fabric.into()),
                (
                    "events",
                    Json::Arr(
                        c.network
                            .events
                            .iter()
                            .map(|e| {
                                obj([
                                    ("at_sec", e.at_sec.into()),
                                    (
                                        "bandwidth_bps",
                                        e.bandwidth_bps.into(),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "service",
            obj([
                ("fc_ms", c.service.fc_ms.into()),
                ("va_alpha_ms", c.service.va_alpha_ms.into()),
                ("va_beta_ms", c.service.va_beta_ms.into()),
                ("cr_alpha_ms", c.service.cr_alpha_ms.into()),
                ("cr_beta_ms", c.service.cr_beta_ms.into()),
                ("tl_ms", c.service.tl_ms.into()),
                ("jitter", c.service.jitter.into()),
                ("online_xi", c.service.online_xi.into()),
                (
                    "compute_events",
                    Json::Arr(
                        c.service
                            .compute_events
                            .iter()
                            .map(|e| match e.node {
                                Some(n) => obj([
                                    ("at_sec", e.at_sec.into()),
                                    ("node", n.into()),
                                    ("factor", e.factor.into()),
                                ]),
                                // `node` omitted = all nodes.
                                None => obj([
                                    ("at_sec", e.at_sec.into()),
                                    ("factor", e.factor.into()),
                                ]),
                            })
                            .collect(),
                    ),
                ),
                (
                    "fault_events",
                    Json::Arr(
                        c.service
                            .fault_events
                            .iter()
                            .map(fault_event_to_json)
                            .collect(),
                    ),
                ),
                (
                    "recovery",
                    obj([
                        ("enabled", c.service.recovery.enabled.into()),
                        (
                            "max_retries",
                            (c.service.recovery.max_retries as usize)
                                .into(),
                        ),
                        (
                            "backoff_base_ms",
                            c.service.recovery.backoff_base_ms.into(),
                        ),
                    ]),
                ),
            ]),
        ),
        (
            "semantics",
            obj([
                ("va_tp", c.semantics.va_tp.into()),
                ("va_fp", c.semantics.va_fp.into()),
                ("cr_tp", c.semantics.cr_tp.into()),
                ("cr_fp", c.semantics.cr_fp.into()),
                ("transit_miss", c.semantics.transit_miss.into()),
                ("fusion_boost", c.semantics.fusion_boost.into()),
            ]),
        ),
        (
            "workload",
            obj([
                ("vertices", c.workload.vertices.into()),
                ("edges", c.workload.edges.into()),
                ("mean_road_m", c.workload.mean_road_m.into()),
                ("fov_m", c.workload.fov_m.into()),
                (
                    "entity_speed_mps",
                    c.workload.entity_speed_mps.into(),
                ),
            ]),
        ),
        (
            "multi_query",
            obj([
                ("num_queries", c.multi_query.num_queries.into()),
                (
                    "mean_interarrival_secs",
                    c.multi_query.mean_interarrival_secs.into(),
                ),
                (
                    "lifetime_secs",
                    c.multi_query.lifetime_secs.into(),
                ),
                ("max_active", c.multi_query.max_active.into()),
                (
                    "max_active_cameras",
                    c.multi_query.max_active_cameras.into(),
                ),
                (
                    "queue_capacity",
                    c.multi_query.queue_capacity.into(),
                ),
                (
                    "priority_levels",
                    (c.multi_query.priority_levels as usize).into(),
                ),
            ]),
        ),
        (
            "obs",
            obj([
                ("ring_capacity", c.obs.ring_capacity.into()),
                (
                    "per_second_metrics",
                    c.obs.per_second_metrics.into(),
                ),
            ]),
        ),
        (
            "sharding",
            obj([
                ("shards", c.sharding.shards.into()),
                ("threads", c.sharding.threads.into()),
            ]),
        ),
        (
            "adaptation",
            obj([
                ("enabled", c.adaptation.enabled.into()),
                (
                    "ladder",
                    Json::Arr(
                        c.adaptation
                            .ladder
                            .iter()
                            .map(|l| {
                                obj([
                                    ("scale", l.scale.into()),
                                    ("cost", l.cost.into()),
                                    ("accuracy", l.accuracy.into()),
                                    (
                                        "stride",
                                        (l.stride as i64).into(),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("slack_down", c.adaptation.slack_down.into()),
                ("slack_up", c.adaptation.slack_up.into()),
                ("cooldown_secs", c.adaptation.cooldown_secs.into()),
            ]),
        ),
    ])
}

pub fn config_from_json(text: &str) -> Result<ExperimentConfig, String> {
    let j = Json::parse(text)?;
    let mut c = ExperimentConfig::default();
    // Every field is optional and defaults to the paper setup, so config
    // files only need to name what they change.
    if let Some(v) = j.get("name").and_then(Json::as_str) {
        c.name = v.to_string();
    }
    set_f64(&j, "duration_secs", &mut c.duration_secs);
    set_f64(&j, "fps", &mut c.fps);
    set_f64(&j, "gamma_ms", &mut c.gamma_ms);
    set_f64(&j, "tl_peak_speed_mps", &mut c.tl_peak_speed_mps);
    set_f64(&j, "eps_max_ms", &mut c.eps_max_ms);
    set_usize(&j, "num_cameras", &mut c.num_cameras);
    if let Some(v) = j.get("seed").and_then(Json::as_f64) {
        c.seed = v as u64;
    }
    if let Some(v) = j.get("probe_every").and_then(Json::as_f64) {
        c.probe_every = v as u64;
    }
    if let Some(v) = j.get("drops_enabled").and_then(Json::as_bool) {
        c.drops_enabled = v;
    }
    if let Some(v) = j.get("seed_last_seen").and_then(Json::as_bool) {
        c.seed_last_seen = v;
    }
    if let Some(v) = j.get("app").and_then(Json::as_str) {
        c.app = app_from_str(v)?;
    }
    if let Some(v) = j.get("tl").and_then(Json::as_str) {
        c.tl = tl_from_str(v)?;
    }
    if let Some(v) = j.get("batching") {
        c.batching = batching_from_json(v)?;
    }
    if let Some(v) = j.get("cluster") {
        set_usize(v, "compute_nodes", &mut c.cluster.compute_nodes);
        set_usize(v, "va_instances", &mut c.cluster.va_instances);
        set_usize(v, "cr_instances", &mut c.cluster.cr_instances);
        set_f64(v, "clock_skew_ms", &mut c.cluster.clock_skew_ms);
    }
    if let Some(v) = j.get("network") {
        set_f64(v, "bandwidth_bps", &mut c.network.bandwidth_bps);
        set_f64(v, "latency_ms", &mut c.network.latency_ms);
        set_usize(v, "frame_bytes", &mut c.network.frame_bytes);
        set_usize(v, "candidate_bytes", &mut c.network.candidate_bytes);
        set_usize(v, "meta_bytes", &mut c.network.meta_bytes);
        if let Some(b) = v.get("shared_fabric").and_then(Json::as_bool) {
            c.network.shared_fabric = b;
        }
        if let Some(evs) = v.get("events").and_then(Json::as_arr) {
            c.network.events = evs
                .iter()
                .map(|e| {
                    Ok(BandwidthEvent {
                        at_sec: e
                            .get("at_sec")
                            .and_then(Json::as_f64)
                            .ok_or("event missing at_sec")?,
                        bandwidth_bps: e
                            .get("bandwidth_bps")
                            .and_then(Json::as_f64)
                            .ok_or("event missing bandwidth_bps")?,
                    })
                })
                .collect::<Result<_, String>>()?;
        }
    }
    if let Some(v) = j.get("service") {
        set_f64(v, "fc_ms", &mut c.service.fc_ms);
        set_f64(v, "va_alpha_ms", &mut c.service.va_alpha_ms);
        set_f64(v, "va_beta_ms", &mut c.service.va_beta_ms);
        set_f64(v, "cr_alpha_ms", &mut c.service.cr_alpha_ms);
        set_f64(v, "cr_beta_ms", &mut c.service.cr_beta_ms);
        set_f64(v, "tl_ms", &mut c.service.tl_ms);
        set_f64(v, "jitter", &mut c.service.jitter);
        if let Some(b) = v.get("online_xi").and_then(Json::as_bool) {
            c.service.online_xi = b;
        }
        if let Some(evs) = v.get("compute_events").and_then(Json::as_arr)
        {
            c.service.compute_events = evs
                .iter()
                .map(|e| {
                    // `node` is validated explicitly: a malformed value
                    // must not silently become "all nodes" (absent) or
                    // node 0 (negative saturating through `as usize`).
                    let node = match e.get("node") {
                        None | Some(Json::Null) => None,
                        Some(n) => {
                            let n = n.as_f64().ok_or(
                                "compute event node must be a number",
                            )?;
                            if n < 0.0 || n.fract() != 0.0 {
                                return Err(format!(
                                    "compute event node must be a non-negative integer, got {n}"
                                ));
                            }
                            Some(n as usize)
                        }
                    };
                    let factor = e
                        .get("factor")
                        .and_then(Json::as_f64)
                        .ok_or("compute event missing factor")?;
                    if !(factor.is_finite() && factor > 0.0) {
                        return Err(format!(
                            "compute event factor must be finite and > 0, got {factor}"
                        ));
                    }
                    Ok(ComputeEvent {
                        at_sec: e
                            .get("at_sec")
                            .and_then(Json::as_f64)
                            .ok_or("compute event missing at_sec")?,
                        node,
                        factor,
                    })
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(evs) = v.get("fault_events").and_then(Json::as_arr) {
            c.service.fault_events = evs
                .iter()
                .map(fault_event_from_json)
                .collect::<Result<_, String>>()?;
        }
        if let Some(r) = v.get("recovery") {
            if let Some(b) = r.get("enabled").and_then(Json::as_bool) {
                c.service.recovery.enabled = b;
            }
            if let Some(n) = r.get("max_retries").and_then(Json::as_f64)
            {
                if n < 0.0 || n.fract() != 0.0 {
                    return Err(format!(
                        "recovery max_retries must be a non-negative integer, got {n}"
                    ));
                }
                c.service.recovery.max_retries = n as u32;
            }
            if let Some(b) =
                r.get("backoff_base_ms").and_then(Json::as_f64)
            {
                if !(b.is_finite() && b > 0.0) {
                    return Err(format!(
                        "recovery backoff_base_ms must be finite and > 0, got {b}"
                    ));
                }
                c.service.recovery.backoff_base_ms = b;
            }
        }
    }
    if let Some(v) = j.get("semantics") {
        set_f64(v, "va_tp", &mut c.semantics.va_tp);
        set_f64(v, "va_fp", &mut c.semantics.va_fp);
        set_f64(v, "cr_tp", &mut c.semantics.cr_tp);
        set_f64(v, "cr_fp", &mut c.semantics.cr_fp);
        set_f64(v, "transit_miss", &mut c.semantics.transit_miss);
        set_f64(v, "fusion_boost", &mut c.semantics.fusion_boost);
    }
    if let Some(v) = j.get("workload") {
        set_usize(v, "vertices", &mut c.workload.vertices);
        set_usize(v, "edges", &mut c.workload.edges);
        set_f64(v, "mean_road_m", &mut c.workload.mean_road_m);
        set_f64(v, "fov_m", &mut c.workload.fov_m);
        set_f64(v, "entity_speed_mps", &mut c.workload.entity_speed_mps);
    }
    if let Some(v) = j.get("multi_query") {
        set_usize(v, "num_queries", &mut c.multi_query.num_queries);
        set_f64(
            v,
            "mean_interarrival_secs",
            &mut c.multi_query.mean_interarrival_secs,
        );
        set_f64(v, "lifetime_secs", &mut c.multi_query.lifetime_secs);
        set_usize(v, "max_active", &mut c.multi_query.max_active);
        set_usize(
            v,
            "max_active_cameras",
            &mut c.multi_query.max_active_cameras,
        );
        set_usize(v, "queue_capacity", &mut c.multi_query.queue_capacity);
        if let Some(p) = v.get("priority_levels").and_then(Json::as_usize)
        {
            c.multi_query.priority_levels = p.min(255) as u8;
        }
    }
    if let Some(v) = j.get("obs") {
        set_usize(v, "ring_capacity", &mut c.obs.ring_capacity);
        if let Some(b) =
            v.get("per_second_metrics").and_then(Json::as_bool)
        {
            c.obs.per_second_metrics = b;
        }
    }
    if let Some(v) = j.get("sharding") {
        set_usize(v, "shards", &mut c.sharding.shards);
        set_usize(v, "threads", &mut c.sharding.threads);
    }
    if let Some(v) = j.get("adaptation") {
        adaptation_from_json(v, &mut c.adaptation)?;
    }
    Ok(c)
}

/// A ladder multiplier: finite and inside `(0, bound]` — a zero or
/// negative multiplier would silently void the stage it scales, and a
/// malformed ladder must be an error, not a default.
fn ladder_multiplier(
    e: &Json,
    key: &str,
    bound: f64,
) -> Result<f64, String> {
    let v = e
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("ladder level missing {key}"))?;
    if !(v.is_finite() && v > 0.0 && v <= bound) {
        return Err(format!(
            "ladder level {key} must be in (0, {bound}], got {v}"
        ));
    }
    Ok(v)
}

fn adaptation_from_json(
    v: &Json,
    out: &mut AdaptationConfig,
) -> Result<(), String> {
    if let Some(b) = v.get("enabled").and_then(Json::as_bool) {
        out.enabled = b;
    }
    if let Some(Json::Arr(levels)) = v.get("ladder") {
        if levels.is_empty() {
            return Err(
                "adaptation ladder must keep the native level".into()
            );
        }
        let mut ladder = Vec::with_capacity(levels.len());
        for l in levels {
            let stride = l
                .get("stride")
                .and_then(Json::as_f64)
                .unwrap_or(1.0);
            if stride < 1.0 || stride.fract() != 0.0 {
                return Err(format!(
                    "ladder level stride must be a positive integer, \
                     got {stride}"
                ));
            }
            ladder.push(ResolutionLevel {
                scale: ladder_multiplier(l, "scale", 1.0)?,
                cost: ladder_multiplier(l, "cost", f64::INFINITY)?,
                accuracy: ladder_multiplier(l, "accuracy", 1.0)?,
                stride: stride as u64,
            });
        }
        if !ladder[0].is_native() {
            return Err(
                "adaptation ladder level 0 must be the native identity \
                 (scale/cost/accuracy 1.0, stride 1)"
                    .into(),
            );
        }
        out.ladder = ladder;
    }
    set_f64(v, "slack_down", &mut out.slack_down);
    set_f64(v, "slack_up", &mut out.slack_up);
    set_f64(v, "cooldown_secs", &mut out.cooldown_secs);
    for (key, s) in
        [("slack_down", out.slack_down), ("slack_up", out.slack_up)]
    {
        if !(s.is_finite() && (0.0..1.0).contains(&s)) {
            return Err(format!(
                "adaptation {key} must be in [0, 1), got {s}"
            ));
        }
    }
    if out.slack_up <= out.slack_down {
        return Err(format!(
            "adaptation slack_up ({}) must exceed slack_down ({}) — \
             the hysteresis band cannot be empty",
            out.slack_up, out.slack_down
        ));
    }
    if !(out.cooldown_secs.is_finite() && out.cooldown_secs >= 0.0) {
        return Err(format!(
            "adaptation cooldown_secs must be finite and >= 0, got {}",
            out.cooldown_secs
        ));
    }
    Ok(())
}

fn fault_event_to_json(e: &FaultEvent) -> Json {
    let mut fields: Vec<(&str, Json)> =
        vec![("at_sec", e.at_sec.into())];
    match e.kind {
        FaultKind::NodeCrash { node, down_secs } => {
            fields.push(("kind", "node_crash".into()));
            fields.push(("node", node.into()));
            // `down_secs` omitted = permanent.
            if let Some(d) = down_secs {
                fields.push(("down_secs", d.into()));
            }
        }
        FaultKind::CameraOutage { camera, down_secs } => {
            fields.push(("kind", "camera_outage".into()));
            fields.push(("camera", camera.into()));
            if let Some(d) = down_secs {
                fields.push(("down_secs", d.into()));
            }
        }
        FaultKind::LinkPartition { a, b, down_secs } => {
            fields.push(("kind", "link_partition".into()));
            fields.push(("a", a.into()));
            fields.push(("b", b.into()));
            if let Some(d) = down_secs {
                fields.push(("down_secs", d.into()));
            }
        }
        FaultKind::MessageLoss { prob, dur_secs } => {
            fields.push(("kind", "message_loss".into()));
            fields.push(("prob", prob.into()));
            if let Some(d) = dur_secs {
                fields.push(("dur_secs", d.into()));
            }
        }
    }
    obj(fields)
}

/// A strictly-validated index field: a malformed value must not
/// silently become index 0 (negative saturating through `as usize`).
fn fault_index(e: &Json, key: &str) -> Result<usize, String> {
    let n = e
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("fault event missing {key}"))?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(format!(
            "fault event {key} must be a non-negative integer, got {n}"
        ));
    }
    Ok(n as usize)
}

/// An optional duration field; present values must be finite and > 0
/// (a zero-length window would be a no-op masquerading as a fault).
fn fault_duration(e: &Json, key: &str) -> Result<Option<f64>, String> {
    match e.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(d) => {
            let d = d
                .as_f64()
                .ok_or_else(|| format!("fault event {key} must be a number"))?;
            if !(d.is_finite() && d > 0.0) {
                return Err(format!(
                    "fault event {key} must be finite and > 0, got {d}"
                ));
            }
            Ok(Some(d))
        }
    }
}

fn fault_event_from_json(e: &Json) -> Result<FaultEvent, String> {
    let at_sec = e
        .get("at_sec")
        .and_then(Json::as_f64)
        .ok_or("fault event missing at_sec")?;
    if !(at_sec.is_finite() && at_sec >= 0.0) {
        return Err(format!(
            "fault event at_sec must be finite and >= 0, got {at_sec}"
        ));
    }
    let kind = match e
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("fault event missing kind")?
    {
        "node_crash" => FaultKind::NodeCrash {
            node: fault_index(e, "node")?,
            down_secs: fault_duration(e, "down_secs")?,
        },
        "camera_outage" => FaultKind::CameraOutage {
            camera: fault_index(e, "camera")?,
            down_secs: fault_duration(e, "down_secs")?,
        },
        "link_partition" => FaultKind::LinkPartition {
            a: fault_index(e, "a")?,
            b: fault_index(e, "b")?,
            down_secs: fault_duration(e, "down_secs")?,
        },
        "message_loss" => {
            let prob = e
                .get("prob")
                .and_then(Json::as_f64)
                .ok_or("message_loss fault missing prob")?;
            if !(prob.is_finite() && (0.0..=1.0).contains(&prob)) {
                return Err(format!(
                    "message_loss prob must be in [0, 1], got {prob}"
                ));
            }
            FaultKind::MessageLoss {
                prob,
                dur_secs: fault_duration(e, "dur_secs")?,
            }
        }
        other => {
            return Err(format!("unknown fault kind {other:?}"))
        }
    };
    Ok(FaultEvent { at_sec, kind })
}

fn set_f64(j: &Json, key: &str, out: &mut f64) {
    if let Some(v) = j.get(key).and_then(Json::as_f64) {
        *out = v;
    }
}

fn set_usize(j: &Json, key: &str, out: &mut usize) {
    if let Some(v) = j.get(key).and_then(Json::as_f64) {
        *out = v as usize;
    }
}

fn app_str(a: AppKind) -> &'static str {
    match a {
        AppKind::App1 => "app1",
        AppKind::App2 => "app2",
        AppKind::App3 => "app3",
        AppKind::App4 => "app4",
    }
}

fn app_from_str(s: &str) -> Result<AppKind, String> {
    Ok(match s {
        "app1" => AppKind::App1,
        "app2" => AppKind::App2,
        "app3" => AppKind::App3,
        "app4" => AppKind::App4,
        other => return Err(format!("unknown app {other:?}")),
    })
}

fn tl_str(t: TlKind) -> &'static str {
    match t {
        TlKind::Base => "base",
        TlKind::Bfs => "bfs",
        TlKind::Wbfs => "wbfs",
        TlKind::WbfsSpeed => "wbfs_speed",
        TlKind::Probabilistic => "probabilistic",
    }
}

fn tl_from_str(s: &str) -> Result<TlKind, String> {
    Ok(match s {
        "base" => TlKind::Base,
        "bfs" => TlKind::Bfs,
        "wbfs" => TlKind::Wbfs,
        "wbfs_speed" => TlKind::WbfsSpeed,
        "probabilistic" => TlKind::Probabilistic,
        other => return Err(format!("unknown tl {other:?}")),
    })
}

fn batching_to_json(b: &BatchingKind) -> Json {
    match b {
        BatchingKind::Static { size } => {
            obj([("kind", "static".into()), ("size", (*size).into())])
        }
        BatchingKind::Dynamic { max } => {
            obj([("kind", "dynamic".into()), ("max", (*max).into())])
        }
        BatchingKind::Nob { max } => {
            obj([("kind", "nob".into()), ("max", (*max).into())])
        }
    }
}

fn batching_from_json(j: &Json) -> Result<BatchingKind, String> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or("batching missing kind")?;
    Ok(match kind {
        "static" => BatchingKind::Static {
            size: j
                .get("size")
                .and_then(Json::as_usize)
                .ok_or("static batching missing size")?,
        },
        "dynamic" => BatchingKind::Dynamic {
            max: j
                .get("max")
                .and_then(Json::as_usize)
                .ok_or("dynamic batching missing max")?,
        },
        "nob" => BatchingKind::Nob {
            max: j
                .get("max")
                .and_then(Json::as_usize)
                .ok_or("nob batching missing max")?,
        },
        other => return Err(format!("unknown batching kind {other:?}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_config_uses_defaults() {
        let c = config_from_json(r#"{"num_cameras": 64, "tl": "wbfs"}"#)
            .unwrap();
        assert_eq!(c.num_cameras, 64);
        assert_eq!(c.tl, TlKind::Wbfs);
        assert_eq!(c.gamma_ms, 15_000.0); // default preserved
    }

    #[test]
    fn bad_enum_is_an_error() {
        assert!(config_from_json(r#"{"app": "app9"}"#).is_err());
        assert!(config_from_json(r#"{"tl": "magic"}"#).is_err());
        assert!(
            config_from_json(r#"{"batching": {"kind": "wild"}}"#).is_err()
        );
    }

    #[test]
    fn multi_query_round_trips() {
        let mut c = ExperimentConfig::default();
        c.multi_query.num_queries = 12;
        c.multi_query.max_active = 5;
        c.multi_query.priority_levels = 4;
        c.multi_query.mean_interarrival_secs = 7.5;
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert_eq!(c2.multi_query.num_queries, 12);
        assert_eq!(c2.multi_query.max_active, 5);
        assert_eq!(c2.multi_query.priority_levels, 4);
        assert!(
            (c2.multi_query.mean_interarrival_secs - 7.5).abs() < 1e-9
        );
        // Omitting the section keeps the defaults.
        let c3 = config_from_json("{}").unwrap();
        assert_eq!(
            c3.multi_query.queue_capacity,
            MultiQueryConfig::default().queue_capacity
        );
    }

    #[test]
    fn compute_events_round_trip() {
        let mut c = ExperimentConfig::default();
        c.service.online_xi = true;
        c.service.compute_events = vec![
            ComputeEvent {
                at_sec: 300.0,
                node: None,
                factor: 4.0,
            },
            ComputeEvent {
                at_sec: 450.0,
                node: Some(3),
                factor: 1.0,
            },
        ];
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert!(c2.service.online_xi);
        assert_eq!(c2.service.compute_events.len(), 2);
        assert_eq!(c2.service.compute_events[0].node, None);
        assert!((c2.service.compute_events[0].factor - 4.0).abs() < 1e-9);
        assert!((c2.service.compute_events[0].at_sec - 300.0).abs() < 1e-9);
        assert_eq!(c2.service.compute_events[1].node, Some(3));
        // A partial config keeps the static defaults.
        let c3 = config_from_json("{}").unwrap();
        assert!(c3.service.compute_events.is_empty());
        assert!(!c3.service.online_xi);
        // A malformed event is an error, not a silent default.
        assert!(config_from_json(
            r#"{"service": {"compute_events": [{"at_sec": 10.0}]}}"#
        )
        .is_err());
        // …including a non-numeric node (must not become "all nodes"),
        // a negative node (must not saturate to node 0), and a
        // non-positive factor.
        for bad in [
            r#"{"service": {"compute_events": [{"at_sec": 1.0, "node": "3", "factor": 4.0}]}}"#,
            r#"{"service": {"compute_events": [{"at_sec": 1.0, "node": -1, "factor": 4.0}]}}"#,
            r#"{"service": {"compute_events": [{"at_sec": 1.0, "factor": 0.0}]}}"#,
        ] {
            assert!(config_from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn fault_events_round_trip() {
        let mut c = ExperimentConfig::default();
        c.service.fault_events = vec![
            FaultEvent {
                at_sec: 120.0,
                kind: FaultKind::NodeCrash {
                    node: 3,
                    down_secs: Some(60.0),
                },
            },
            FaultEvent {
                at_sec: 200.0,
                kind: FaultKind::NodeCrash {
                    node: 1,
                    down_secs: None,
                },
            },
            FaultEvent {
                at_sec: 10.0,
                kind: FaultKind::CameraOutage {
                    camera: 17,
                    down_secs: Some(5.0),
                },
            },
            FaultEvent {
                at_sec: 30.0,
                kind: FaultKind::LinkPartition {
                    a: 0,
                    b: 4,
                    down_secs: Some(15.0),
                },
            },
            FaultEvent {
                at_sec: 50.0,
                kind: FaultKind::MessageLoss {
                    prob: 0.1,
                    dur_secs: None,
                },
            },
        ];
        c.service.recovery = RecoveryConfig {
            enabled: false,
            max_retries: 5,
            backoff_base_ms: 125.0,
        };
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert_eq!(c2.service.fault_events, c.service.fault_events);
        assert_eq!(c2.service.recovery, c.service.recovery);
        // A partial config keeps the failure-free defaults.
        let c3 = config_from_json("{}").unwrap();
        assert!(c3.service.fault_events.is_empty());
        assert!(c3.service.recovery.enabled);
        // Malformed events are errors, not silent defaults.
        for bad in [
            r#"{"service": {"fault_events": [{"at_sec": 1.0}]}}"#,
            r#"{"service": {"fault_events": [{"at_sec": 1.0, "kind": "volcano"}]}}"#,
            r#"{"service": {"fault_events": [{"at_sec": 1.0, "kind": "node_crash", "node": -1}]}}"#,
            r#"{"service": {"fault_events": [{"at_sec": 1.0, "kind": "node_crash", "node": 2, "down_secs": 0.0}]}}"#,
            r#"{"service": {"fault_events": [{"at_sec": 1.0, "kind": "message_loss", "prob": 1.5}]}}"#,
            r#"{"service": {"fault_events": [{"at_sec": 1.0, "kind": "link_partition", "a": 0}]}}"#,
            r#"{"service": {"recovery": {"max_retries": -2}}}"#,
            r#"{"service": {"recovery": {"backoff_base_ms": 0.0}}}"#,
        ] {
            assert!(config_from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn obs_round_trips() {
        let mut c = ExperimentConfig::default();
        c.obs.ring_capacity = 251;
        c.obs.per_second_metrics = false;
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert_eq!(c2.obs.ring_capacity, 251);
        assert!(!c2.obs.per_second_metrics);
        // Omitting the section keeps the defaults.
        let c3 = config_from_json("{}").unwrap();
        assert_eq!(c3.obs.ring_capacity, 4093);
        assert!(c3.obs.per_second_metrics);
    }

    #[test]
    fn sharding_round_trips() {
        let mut c = ExperimentConfig::default();
        c.sharding.shards = 4;
        c.sharding.threads = 4;
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert_eq!(c2.sharding.shards, 4);
        assert_eq!(c2.sharding.threads, 4);
        // Omitting the section keeps the single-shard default.
        let c3 = config_from_json("{}").unwrap();
        assert_eq!(c3.sharding.shards, 1);
        assert_eq!(c3.sharding.threads, 0);
    }

    #[test]
    fn adaptation_round_trips() {
        let mut c = ExperimentConfig::default();
        c.adaptation.enabled = true;
        c.adaptation.ladder.push(ResolutionLevel {
            scale: 0.5,
            cost: 0.55,
            accuracy: 0.97,
            stride: 2,
        });
        c.adaptation.slack_down = 0.2;
        c.adaptation.slack_up = 0.7;
        c.adaptation.cooldown_secs = 3.0;
        let j = config_to_json(&c).to_string();
        let c2 = config_from_json(&j).unwrap();
        assert_eq!(c2.adaptation, c.adaptation);
        // Omitting the section keeps the identity default.
        let c3 = config_from_json("{}").unwrap();
        assert!(c3.adaptation.is_identity());
    }

    #[test]
    fn adaptation_rejects_malformed_ladders() {
        let bad = [
            // Empty ladder loses the native level.
            r#"{"adaptation": {"ladder": []}}"#,
            // Level 0 must be the exact identity.
            r#"{"adaptation": {"ladder": [
                {"scale": 0.5, "cost": 0.5, "accuracy": 1.0, "stride": 1}
            ]}}"#,
            // Multipliers must be in range — error, not default.
            r#"{"adaptation": {"ladder": [
                {"scale": 1.0, "cost": 1.0, "accuracy": 1.0, "stride": 1},
                {"scale": 0.5, "cost": -2.0, "accuracy": 1.0, "stride": 1}
            ]}}"#,
            r#"{"adaptation": {"ladder": [
                {"scale": 1.0, "cost": 1.0, "accuracy": 1.0, "stride": 1},
                {"scale": 0.5, "cost": 0.5, "accuracy": 1.5, "stride": 1}
            ]}}"#,
            // Fractional or zero strides are nonsense.
            r#"{"adaptation": {"ladder": [
                {"scale": 1.0, "cost": 1.0, "accuracy": 1.0, "stride": 1},
                {"scale": 0.5, "cost": 0.5, "accuracy": 0.9, "stride": 0.5}
            ]}}"#,
            // An empty hysteresis band would thrash.
            r#"{"adaptation": {"slack_down": 0.5, "slack_up": 0.4}}"#,
            r#"{"adaptation": {"cooldown_secs": -1.0}}"#,
        ];
        for text in bad {
            assert!(
                config_from_json(text).is_err(),
                "accepted malformed adaptation config: {text}"
            );
        }
    }

    #[test]
    fn every_preset_round_trips() {
        for name in super::super::PRESETS {
            let c = preset(name);
            let j = config_to_json(&c).to_string();
            let c2 = config_from_json(&j).unwrap();
            assert_eq!(c2.name, c.name);
            assert_eq!(c2.app, c.app);
            assert_eq!(c2.tl, c.tl);
            assert_eq!(c2.batching.label(), c.batching.label());
            assert_eq!(c2.num_cameras, c.num_cameras);
            assert_eq!(c2.drops_enabled, c.drops_enabled);
            assert_eq!(c2.network.events.len(), c.network.events.len());
            assert_eq!(
                c2.service.compute_events.len(),
                c.service.compute_events.len()
            );
            assert_eq!(c2.service.online_xi, c.service.online_xi);
            assert!(
                (c2.service.cr_alpha_ms - c.service.cr_alpha_ms).abs()
                    < 1e-9
            );
        }
    }
}
