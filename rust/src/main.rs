//! `anveshak` — the launcher CLI (§3's Master entry point).
//!
//! Subcommands:
//!   sim   [--preset NAME | --config FILE.json] [--out results/]
//!         Run an experiment on the virtual-time engine and print the
//!         run summary (fast; used by the harness presets too).
//!   serve [--config FILE.json] [--cameras N] [--secs S]
//!         Run the live engine: real clocks, real PJRT models.
//!   presets
//!         List the named experiment presets.

use std::path::PathBuf;

use anveshak::config::{preset, ExperimentConfig, PRESETS};
use anveshak::coordinator::des;
use anveshak::coordinator::LiveEngine;
use anveshak::runtime::default_dir;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("presets") => {
            for p in PRESETS {
                println!("{p}");
            }
            Ok(())
        }
        Some("sim") => cmd_sim(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        _ => {
            eprintln!(
                "usage: anveshak <sim|serve|presets> [options]\n  see --help of each subcommand"
            );
            std::process::exit(2);
        }
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn load_cfg(args: &[String]) -> anyhow::Result<ExperimentConfig> {
    if let Some(name) = flag_value(args, "--preset") {
        return Ok(preset(name));
    }
    if let Some(path) = flag_value(args, "--config") {
        return ExperimentConfig::from_file(&PathBuf::from(path));
    }
    Ok(ExperimentConfig::default())
}

fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    let cfg = load_cfg(args)?;
    let name = cfg.name.clone();
    println!(
        "simulating {name}: {} cameras, {:.0}s, {} batching, TL {:?}, drops {}",
        cfg.num_cameras,
        cfg.duration_secs,
        cfg.batching.label(),
        cfg.tl,
        cfg.drops_enabled
    );
    let start = std::time::Instant::now();
    let r = des::run(cfg);
    let s = &r.summary;
    println!(
        "done in {:.1}s wall: generated {} | on-time {} | delayed {} | dropped {} | lost-to-fault {} | in-flight {}",
        start.elapsed().as_secs_f64(),
        s.generated,
        s.on_time,
        s.delayed,
        s.dropped,
        s.lost_to_fault,
        s.in_flight
    );
    println!(
        "latency: median {:.2}s p99 {:.2}s max {:.2}s | detections {} | peak active cams {}",
        s.latency.median, s.latency.p99, s.latency.max, r.detections, r.peak_active
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let mut cfg = load_cfg(args)?;
    // Live-mode defaults: a laptop-scale network unless overridden.
    if flag_value(args, "--preset").is_none()
        && flag_value(args, "--config").is_none()
    {
        cfg.num_cameras = 16;
        cfg.workload.vertices = 60;
        cfg.workload.edges = 150;
        cfg.duration_secs = 10.0;
        cfg.fps = 2.0;
        cfg.gamma_ms = 5_000.0;
        cfg.cluster.va_instances = 2;
        cfg.cluster.cr_instances = 2;
    }
    if let Some(n) = flag_value(args, "--cameras") {
        cfg.num_cameras = n.parse()?;
    }
    if let Some(s) = flag_value(args, "--secs") {
        cfg.duration_secs = s.parse()?;
    }
    // The config names a stock composition; the engine only sees its
    // AppDefinition (custom apps pass their own to LiveEngine::new).
    let app = anveshak::apps::resolve(&cfg);
    println!(
        "serving {} for {:.0}s: {} cameras, VA={} CR={} (real PJRT models)",
        app.name,
        cfg.duration_secs,
        cfg.num_cameras,
        app.va_variant.artifact_name(),
        app.cr_variant.artifact_name()
    );
    let eng = LiveEngine::new(cfg, default_dir(), app);
    let r = eng.run()?;
    println!(
        "wall {:.1}s | throughput {:.1} fps | generated {} on-time {} delayed {} dropped {} lost-to-fault {}",
        r.wall_secs,
        r.throughput,
        r.summary.generated,
        r.summary.on_time,
        r.summary.delayed,
        r.summary.dropped,
        r.summary.lost_to_fault
    );
    println!(
        "latency median {:.2}s p99 {:.2}s | detections {} | peak active {}",
        r.summary.latency.median,
        r.summary.latency.p99,
        r.detections,
        r.peak_active
    );
    Ok(())
}
