//! The stock block library: ready-made implementations of the
//! [`crate::dataflow`] UDF traits that the Table-1 applications (and
//! most user apps) compose from.
//!
//! Every block here is `Clone`, so [`crate::apps::AppBuilder`] can turn
//! it into a factory (engines mint one instance per worker / per
//! query). None of the simulated blocks allocates on the per-batch
//! path, and all randomness flows through the engine-owned RNG in
//! [`SimCtx`] — runs stay bit-reproducible per seed.

use crate::dataflow::{
    boosted_rates, boosted_residual, ContentionResolver, Event,
    FilterControl, ModelVariant, Payload, QueryFusion, QueryId,
    ScoreParams, SimCtx, VideoAnalytics,
};
use crate::config::WorkloadConfig;
use crate::util::{FastMap, Micros};

// ---------------------------------------------------------------------------
// Filter Controls
// ---------------------------------------------------------------------------

/// The §2.2.1 default FC: forward a frame iff TL has the camera active.
#[derive(Debug, Clone, Copy, Default)]
pub struct ActiveFlagFc;

impl FilterControl for ActiveFlagFc {
    fn admit(
        &mut self,
        _query: QueryId,
        _camera: usize,
        _frame_no: u64,
        _now: Micros,
        active: bool,
    ) -> bool {
        active
    }

    fn label(&self) -> &'static str {
        "active-flag"
    }
}

/// App 3's FC: frame-rate control for fast entities. At the Table-1
/// calibration (`stride = 1`) it forwards every active frame — the
/// rate knob shows up through the workload it tunes (vehicle speeds
/// raise the spotlight expansion rate) — while `stride > 1` decimates
/// the per-camera frame rate.
#[derive(Debug, Clone, Copy)]
pub struct FrameRateFc {
    /// Forward every `stride`-th frame of an active camera (≥ 1).
    pub stride: u64,
    /// Floor for the entity speed this FC assumes (vehicles).
    pub min_entity_speed_mps: f64,
    /// Floor for TL's peak expansion speed.
    pub min_peak_speed_mps: f64,
}

impl FrameRateFc {
    /// Table-1 calibration (vehicle speeds, full frame rate).
    pub fn vehicle() -> Self {
        Self {
            stride: 1,
            min_entity_speed_mps: 8.0,
            min_peak_speed_mps: 14.0,
        }
    }
}

impl FilterControl for FrameRateFc {
    fn admit(
        &mut self,
        _query: QueryId,
        _camera: usize,
        frame_no: u64,
        _now: Micros,
        active: bool,
    ) -> bool {
        active && (self.stride <= 1 || frame_no % self.stride == 0)
    }

    fn tune_workload(
        &self,
        workload: &mut WorkloadConfig,
        tl_peak_speed_mps: &mut f64,
    ) {
        // The entity defaults to vehicle speeds in this app.
        workload.entity_speed_mps =
            workload.entity_speed_mps.max(self.min_entity_speed_mps);
        *tl_peak_speed_mps =
            tl_peak_speed_mps.max(self.min_peak_speed_mps);
    }

    fn label(&self) -> &'static str {
        "frame-rate"
    }
}

/// DeepScale-style adaptive frame-rate FC (App 5): run a camera at full
/// rate for its first `warmup_frames` frames after (re)activation — the
/// reacquisition-critical window — then decimate to every
/// `steady_stride`-th frame. Cuts steady-state VA load ~`stride`×
/// without touching the platform's batching/dropping.
#[derive(Debug, Clone)]
pub struct AdaptiveRateFc {
    pub steady_stride: u64,
    pub warmup_frames: u64,
    /// Floors applied at composition time (vehicle workload).
    pub min_entity_speed_mps: f64,
    pub min_peak_speed_mps: f64,
    /// (query, camera) -> frames admitted-or-skipped since activation.
    seen: FastMap<u64, u64>,
}

impl AdaptiveRateFc {
    pub fn new(steady_stride: u64, warmup_frames: u64) -> Self {
        Self {
            steady_stride: steady_stride.max(1),
            warmup_frames,
            min_entity_speed_mps: 8.0,
            min_peak_speed_mps: 14.0,
            seen: FastMap::default(),
        }
    }
}

impl FilterControl for AdaptiveRateFc {
    fn admit(
        &mut self,
        query: QueryId,
        camera: usize,
        frame_no: u64,
        _now: Micros,
        active: bool,
    ) -> bool {
        let key = ((query as u64) << 32) | camera as u64;
        if !active {
            // Deactivation resets the warm-up window.
            self.seen.remove(&key);
            return false;
        }
        let n = self.seen.entry(key).or_insert(0);
        let admit =
            *n < self.warmup_frames || frame_no % self.steady_stride == 0;
        *n += 1;
        admit
    }

    fn tune_workload(
        &self,
        workload: &mut WorkloadConfig,
        tl_peak_speed_mps: &mut f64,
    ) {
        workload.entity_speed_mps =
            workload.entity_speed_mps.max(self.min_entity_speed_mps);
        *tl_peak_speed_mps =
            tl_peak_speed_mps.max(self.min_peak_speed_mps);
    }

    fn forget_query(&mut self, query: QueryId) {
        self.seen.retain(|&k, _| (k >> 32) != query as u64);
    }

    fn label(&self) -> &'static str {
        "adaptive-rate"
    }
}

// ---------------------------------------------------------------------------
// Video Analytics
// ---------------------------------------------------------------------------

/// Seeded avalanche hash for the whole-transit miss coin: real re-id
/// misses entire tracks (occlusion, pose), which is what produces the
/// paper's long blind-spot spells. Deterministic per (seed, query,
/// camera, transit), and independent of the engine RNG stream. The
/// query term vanishes for `SINGLE_QUERY` (= 0), so single- and
/// multi-query engines share one formula.
fn transit_coin(seed: u64, query: QueryId, camera: usize, idx: usize) -> f64 {
    let mut h = seed
        ^ (query as u64).wrapping_mul(0xB529_7A4D)
        ^ (camera as u64).wrapping_mul(0x9E37_79B9)
        ^ (idx as u64).wrapping_mul(0xC2B2_AE35);
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    h as f64 / u64::MAX as f64
}

/// The stock VA block: on the DES path it detects against ground-truth
/// labels (per-frame true/false-positive coins plus the whole-transit
/// miss model); on the live path it carries the backend's match score
/// into the `Candidate` payload (1:1 selectivity — every frame flows
/// on, CR resolves).
#[derive(Debug, Clone, Copy)]
pub struct SimDetector {
    variant: ModelVariant,
    cost: f64,
    label: &'static str,
}

impl SimDetector {
    /// A detector running `variant`, costed from the typed
    /// [`crate::dataflow::VARIANT_TABLE`] — picking a variant can never
    /// silently miss its ξ multiplier. [`Self::with_cost`] still
    /// overrides for app-specific calibrations.
    pub fn new(variant: ModelVariant) -> Self {
        Self {
            variant,
            cost: variant.profile().xi,
            label: "detector",
        }
    }

    /// HoG-class person detector (App 1/2 calibration).
    pub fn hog() -> Self {
        Self::new(ModelVariant::Va).labeled("hog")
    }

    /// YOLO-class vehicle detector — heavier than HoG (App 3).
    pub fn yolo() -> Self {
        Self::new(ModelVariant::Va).with_cost(2.5).labeled("yolo")
    }

    /// Small re-id network run *in VA* (App 4's two-stage pipeline).
    pub fn reid_small() -> Self {
        Self::new(ModelVariant::CrSmall)
            .with_cost(3.0)
            .labeled("reid-small")
    }

    /// Service-cost multiplier relative to App 1's VA profile.
    pub fn with_cost(mut self, cost: f64) -> Self {
        self.cost = cost;
        self
    }

    pub fn labeled(mut self, label: &'static str) -> Self {
        self.label = label;
        self
    }
}

impl VideoAnalytics for SimDetector {
    fn step_sim(&mut self, events: &mut [Event], ctx: &mut SimCtx<'_>) {
        for ev in events.iter_mut() {
            if let Payload::Frame { entity_present } = ev.payload {
                // The feedback edge: once QF has refined this query's
                // embedding, whole-transit misses become rarer (the
                // sharper target survives occlusion/pose changes). The
                // transit coin is a hash, not an RNG draw, so the
                // refined threshold never shifts the engine RNG stream.
                let miss_p = if ctx
                    .feedback
                    .refined(ev.header.query)
                    .is_some()
                {
                    boosted_residual(
                        ctx.sem.fusion_boost,
                        ctx.sem.transit_miss,
                    )
                } else {
                    ctx.sem.transit_miss
                };
                let transit_missed = entity_present
                    && ctx
                        .truth
                        .interval_index(
                            ev.header.query,
                            ev.header.camera,
                            ev.header.captured,
                        )
                        .map(|idx| {
                            transit_coin(
                                ctx.seed,
                                ev.header.query,
                                ev.header.camera,
                                idx,
                            ) < miss_p
                        })
                        .unwrap_or(false);
                // Adaptation plane: a downshifted camera detects with
                // reduced recall. Exactly 1.0 at the identity ladder
                // (`p * 1.0` is bit-exact) and threshold-only — the
                // RNG draw count never changes.
                let acc = ctx.accuracy(ev.header.camera, self.variant);
                let flagged = if entity_present && !transit_missed {
                    ctx.rng.bool(ctx.sem.va_tp * acc)
                } else if entity_present {
                    false // transit missed entirely
                } else {
                    ctx.rng.bool(ctx.sem.va_fp)
                };
                ev.payload = Payload::Candidate {
                    entity_present,
                    score: if flagged { 0.9 } else { 0.1 },
                };
            }
        }
    }

    fn apply_scores(
        &mut self,
        events: &mut [Event],
        scores: &[f32],
        _params: &ScoreParams,
    ) {
        for (ev, &score) in events.iter_mut().zip(scores) {
            // Ground-truth frames (service front) become scored
            // candidates; pixel frames (live engine) flow on 1:1 — the
            // real VA is a detector, CR resolves the identity.
            if let Payload::Frame { entity_present } = ev.payload {
                ev.payload = Payload::Candidate {
                    entity_present,
                    score,
                };
            }
        }
    }

    fn variant(&self) -> ModelVariant {
        self.variant
    }

    fn cost(&self) -> f64 {
        self.cost
    }

    fn label(&self) -> &'static str {
        self.label
    }
}

// ---------------------------------------------------------------------------
// Contention Resolution
// ---------------------------------------------------------------------------

/// The stock CR block: re-identification of VA candidates against the
/// query identity. DES path draws the confirm/false-positive coins;
/// live path thresholds the backend's match score (gating on the VA
/// score when the payload carries one). Confirmed detections are
/// flagged `avoid_drop` (§4.3.3: positive matches must not be dropped).
#[derive(Debug, Clone, Copy)]
pub struct SimReid {
    variant: ModelVariant,
    cost: f64,
    label: &'static str,
}

impl SimReid {
    /// A re-id block running `variant`, costed from the typed
    /// [`crate::dataflow::VARIANT_TABLE`] — the 1.63x CrLarge
    /// multiplier comes with the variant, not from a per-call-site
    /// constant that a new app could forget.
    pub fn new(variant: ModelVariant) -> Self {
        Self {
            variant,
            cost: variant.profile().xi,
            label: "reid",
        }
    }

    /// OpenReid-class small network (App 1 calibration).
    pub fn small() -> Self {
        Self::new(ModelVariant::CrSmall).labeled("reid-small")
    }

    /// The deeper CR DNN (~1.63x slower per frame, App 2/4) — the
    /// cost multiplier rides in from the variant table.
    pub fn large() -> Self {
        Self::new(ModelVariant::CrLarge).labeled("reid-large")
    }

    /// BoxCars-class vehicle re-id (App 3).
    pub fn vehicle() -> Self {
        Self::new(ModelVariant::CrSmall)
            .with_cost(1.2)
            .labeled("reid-vehicle")
    }

    pub fn with_cost(mut self, cost: f64) -> Self {
        self.cost = cost;
        self
    }

    pub fn labeled(mut self, label: &'static str) -> Self {
        self.label = label;
        self
    }
}

impl ContentionResolver for SimReid {
    fn step_sim(&mut self, events: &mut [Event], ctx: &mut SimCtx<'_>) {
        for ev in events.iter_mut() {
            if let Payload::Candidate {
                entity_present,
                score,
            } = ev.payload
            {
                let candidate = score > 0.5;
                // Feedback edge: a refined query embedding shrinks the
                // residual re-id error rates by `fusion_boost`. Same
                // draw count either way (only the thresholds move), so
                // non-refined queries keep the exact RNG stream.
                let (tp, fp) = if ctx
                    .feedback
                    .refined(ev.header.query)
                    .is_some()
                {
                    boosted_rates(
                        ctx.sem.fusion_boost,
                        ctx.sem.cr_tp,
                        ctx.sem.cr_fp,
                    )
                } else {
                    (ctx.sem.cr_tp, ctx.sem.cr_fp)
                };
                // Adaptation plane: reduced resolution / a lighter CR
                // variant lowers the confirm rate. Threshold-only and
                // exactly 1.0 at the identity ladder, like the
                // fusion-boost path above.
                let acc = ctx.accuracy(ev.header.camera, self.variant);
                let detected = if entity_present && candidate {
                    ctx.rng.bool(tp * acc)
                } else {
                    candidate && ctx.rng.bool(fp)
                };
                if detected {
                    // Positive matches must not be dropped (§4.3.3).
                    ev.header.avoid_drop = true;
                }
                ev.payload = Payload::Detection {
                    detected,
                    confidence: if detected { 0.95 } else { 0.05 },
                };
            }
        }
    }

    fn apply_scores(
        &mut self,
        events: &mut [Event],
        scores: &[f32],
        params: &ScoreParams,
    ) {
        for (ev, &score) in events.iter_mut().zip(scores) {
            let detected = match ev.payload {
                // Service front: VA's score gates the CR verdict.
                Payload::Candidate {
                    score: va_score, ..
                } => va_score > 0.5 && score > params.threshold,
                // Live engine: the pixels went straight through VA.
                _ => score > params.threshold,
            };
            if detected {
                ev.header.avoid_drop = true;
            }
            ev.payload = Payload::Detection {
                detected,
                confidence: score,
            };
        }
    }

    fn variant(&self) -> ModelVariant {
        self.variant
    }

    fn cost(&self) -> f64 {
        self.cost
    }

    fn label(&self) -> &'static str {
        self.label
    }
}

// ---------------------------------------------------------------------------
// Query Fusion
// ---------------------------------------------------------------------------

/// No query fusion (Table-1 apps 1, 3, 4).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoFusion;

impl QueryFusion for NoFusion {
    fn label(&self) -> &'static str {
        "none"
    }
}

/// App 2's RNN-style fusion: fold high-confidence detections into a
/// running query embedding with exponential decay. Deterministic and
/// RNG-free, so enabling it never perturbs the engines' seeded draws —
/// fusion refines the embedding, the tuning triangle is untouched.
#[derive(Debug, Clone)]
pub struct RnnFusion {
    momentum: f32,
    min_confidence: f32,
    state: Vec<f32>,
    updates: u64,
}

impl RnnFusion {
    pub fn new(dim: usize, momentum: f32, min_confidence: f32) -> Self {
        Self {
            momentum,
            min_confidence,
            state: vec![0.0; dim.max(1)],
            updates: 0,
        }
    }

    pub fn updates(&self) -> u64 {
        self.updates
    }
}

impl Default for RnnFusion {
    fn default() -> Self {
        Self::new(8, 0.9, 0.9)
    }
}

impl QueryFusion for RnnFusion {
    fn on_detection(&mut self, ev: &Event) -> bool {
        let Payload::Detection {
            detected: true,
            confidence,
        } = ev.payload
        else {
            return false;
        };
        if confidence < self.min_confidence {
            return false;
        }
        // Pseudo-embedding of the sighting: a camera-seeded direction
        // scaled by confidence (the live QF model replaces this).
        let cam = ev.header.camera as u64;
        for (i, s) in self.state.iter_mut().enumerate() {
            let mut h = cam
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(i as u64);
            h ^= h >> 33;
            let feat =
                (h as f64 / u64::MAX as f64) as f32 * confidence;
            *s = self.momentum * *s + (1.0 - self.momentum) * feat;
        }
        self.updates += 1;
        true
    }

    fn embedding(&self) -> Option<&[f32]> {
        Some(&self.state)
    }

    fn fuses(&self) -> bool {
        true
    }

    fn label(&self) -> &'static str {
        "rnn-fusion"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::SINGLE_QUERY;

    #[test]
    fn active_flag_follows_tl() {
        let mut fc = ActiveFlagFc;
        assert!(fc.admit(SINGLE_QUERY, 3, 0, 0, true));
        assert!(!fc.admit(SINGLE_QUERY, 3, 1, 0, false));
    }

    #[test]
    fn frame_rate_stride_decimates() {
        let mut fc = FrameRateFc {
            stride: 3,
            ..FrameRateFc::vehicle()
        };
        let admitted = (0..9u64)
            .filter(|&f| fc.admit(SINGLE_QUERY, 0, f, 0, true))
            .count();
        assert_eq!(admitted, 3);
        // Table-1 calibration forwards everything.
        let mut fc1 = FrameRateFc::vehicle();
        assert!((0..9u64).all(|f| fc1.admit(SINGLE_QUERY, 0, f, 0, true)));
    }

    #[test]
    fn frame_rate_tunes_vehicle_workload() {
        let mut w = WorkloadConfig::default();
        let mut peak = 4.0;
        FrameRateFc::vehicle().tune_workload(&mut w, &mut peak);
        assert!(w.entity_speed_mps >= 8.0);
        assert!(peak >= 14.0);
    }

    #[test]
    fn adaptive_rate_warms_up_then_decimates() {
        let mut fc = AdaptiveRateFc::new(4, 3);
        // First 3 frames after activation always admitted.
        assert!(fc.admit(0, 7, 1, 0, true));
        assert!(fc.admit(0, 7, 2, 0, true));
        assert!(fc.admit(0, 7, 3, 0, true));
        // Steady state: only multiples of the stride.
        assert!(fc.admit(0, 7, 4, 0, true) == (4 % 4 == 0));
        assert!(!fc.admit(0, 7, 5, 0, true));
        // Deactivation resets the warm-up window.
        assert!(!fc.admit(0, 7, 6, 0, false));
        assert!(fc.admit(0, 7, 7, 0, true));
    }

    #[test]
    fn reid_scores_gate_on_va_and_threshold() {
        let mut cr = SimReid::small();
        let mut evs = vec![
            Event {
                header: crate::dataflow::Header::new(0, 0, 0, 0),
                payload: Payload::Candidate {
                    entity_present: true,
                    score: 0.9,
                },
            },
            Event {
                header: crate::dataflow::Header::new(1, 0, 0, 0),
                payload: Payload::Candidate {
                    entity_present: true,
                    score: 0.1, // VA said no: CR cannot confirm
                },
            },
        ];
        cr.apply_scores(
            &mut evs,
            &[0.8, 0.8],
            &ScoreParams { threshold: 0.5 },
        );
        assert!(matches!(
            evs[0].payload,
            Payload::Detection { detected: true, .. }
        ));
        assert!(evs[0].header.avoid_drop);
        assert!(matches!(
            evs[1].payload,
            Payload::Detection {
                detected: false,
                ..
            }
        ));
    }

    #[test]
    fn rnn_fusion_updates_on_confident_detections() {
        let mut qf = RnnFusion::default();
        let det = Event {
            header: crate::dataflow::Header::new(0, 4, 0, 0),
            payload: Payload::Detection {
                detected: true,
                confidence: 0.95,
            },
        };
        let neg = Event {
            header: crate::dataflow::Header::new(1, 4, 0, 0),
            payload: Payload::Detection {
                detected: false,
                confidence: 0.05,
            },
        };
        assert!(qf.on_detection(&det));
        assert!(!qf.on_detection(&neg));
        assert_eq!(qf.updates(), 1);
        assert!(qf.fuses());
        let emb = qf.embedding().unwrap().to_vec();
        assert!(emb.iter().any(|&x| x != 0.0));
        // Deterministic: same inputs, same embedding.
        let mut qf2 = RnnFusion::default();
        qf2.on_detection(&det);
        assert_eq!(qf2.embedding().unwrap(), &emb[..]);
    }

    #[test]
    fn no_fusion_is_inert() {
        let mut qf = NoFusion;
        let det = Event {
            header: crate::dataflow::Header::new(0, 4, 0, 0),
            payload: Payload::Detection {
                detected: true,
                confidence: 0.95,
            },
        };
        assert!(!qf.on_detection(&det));
        assert!(!qf.fuses());
        assert!(qf.embedding().is_none());
    }
}
