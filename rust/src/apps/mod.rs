//! The four illustrative tracking applications (Table 1).
//!
//! Each app is a composition of user logic over the fixed dataflow:
//!
//! | App | FC | VA | CR | TL | QF |
//! |-----|----|----|----|----|----|
//! | 1 | Active? | HoG-like features | Re-id (small) | WBFS | — |
//! | 2 | Active? | HoG-like features | Re-id (large) | BFS | RNN-fusion |
//! | 3 | FrameRate | YOLO-like (cars) | Car re-id | WBFS w/ speed | — |
//! | 4 | Active? | Re-id (small) | Re-id (large) | Probabilistic | — |
//!
//! [`AppSpec::apply`] configures an [`ExperimentConfig`] for the DES
//! engine; the `*_variant` names select AOT artifacts for the live
//! engine.

use crate::config::{AppKind, ExperimentConfig, TlKind};

/// Composition of one tracking application.
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub kind: AppKind,
    pub name: &'static str,
    pub description: &'static str,
    /// FC user logic: simple active flag vs frame-rate control.
    pub fc_logic: &'static str,
    /// AOT model variant the live VA stage runs.
    pub va_variant: &'static str,
    /// AOT model variant the live CR stage runs.
    pub cr_variant: &'static str,
    /// Default tracking logic.
    pub tl: TlKind,
    /// Whether query fusion runs on high-confidence detections.
    pub qf: bool,
    /// CR per-frame cost multiplier relative to App 1's CR (the paper
    /// reports App 2's CR at ~1.63x).
    pub cr_cost: f64,
    /// VA cost multiplier (App 4 runs a DNN in VA, not HoG).
    pub va_cost: f64,
}

/// Table-1 composition for an application.
pub fn spec(kind: AppKind) -> AppSpec {
    match kind {
        AppKind::App1 => AppSpec {
            kind,
            name: "App1-person",
            description: "Missing-person tracking: HoG VA, OpenReid-class \
                          CR, weighted-BFS spotlight.",
            fc_logic: "active-flag",
            va_variant: "va",
            cr_variant: "cr_small",
            tl: TlKind::Wbfs,
            qf: false,
            cr_cost: 1.0,
            va_cost: 1.0,
        },
        AppKind::App2 => AppSpec {
            kind,
            name: "App2-person-fusion",
            description: "Person tracking with a deeper CR DNN and \
                          RNN-style query fusion.",
            fc_logic: "active-flag",
            va_variant: "va",
            cr_variant: "cr_large",
            tl: TlKind::Bfs,
            qf: true,
            cr_cost: 1.63,
            va_cost: 1.0,
        },
        AppKind::App3 => AppSpec {
            kind,
            name: "App3-vehicle",
            description: "Stolen-vehicle tracking: YOLO-class VA, BoxCars \
                          CR, speed-aware WBFS with FC frame-rate control.",
            fc_logic: "frame-rate",
            va_variant: "va",
            cr_variant: "cr_small",
            tl: TlKind::WbfsSpeed,
            qf: false,
            cr_cost: 1.2,
            va_cost: 2.5, // YOLO-class detector is heavier than HoG
        },
        AppKind::App4 => AppSpec {
            kind,
            name: "App4-two-stage",
            description: "Two-stage re-id (small model in VA, large in CR) \
                          with Naive-Bayes path-likelihood TL.",
            fc_logic: "active-flag",
            va_variant: "cr_small",
            cr_variant: "cr_large",
            tl: TlKind::Probabilistic,
            qf: false,
            cr_cost: 1.63,
            va_cost: 3.0,
        },
    }
}

impl AppSpec {
    /// Configure an experiment for this application: tracking logic and
    /// the per-stage service-cost scaling relative to App 1's profile.
    ///
    /// Leaves `cfg.tl` alone if the caller already overrode it (the §5
    /// experiments sweep TL independent of the app).
    pub fn apply(&self, cfg: &mut ExperimentConfig, override_tl: bool) {
        cfg.app = self.kind;
        if override_tl {
            cfg.tl = self.tl;
        }
        cfg.service.cr_alpha_ms *= self.cr_cost;
        cfg.service.cr_beta_ms *= self.cr_cost;
        cfg.service.va_alpha_ms *= self.va_cost;
        cfg.service.va_beta_ms *= self.va_cost;
        if matches!(self.fc_logic, "frame-rate") {
            // App 3's FC throttles the frame rate for slow targets; the
            // entity defaults to vehicle speeds in that app.
            cfg.workload.entity_speed_mps =
                cfg.workload.entity_speed_mps.max(8.0);
            cfg.tl_peak_speed_mps = cfg.tl_peak_speed_mps.max(14.0);
        }
    }
}

/// All four app specs.
pub fn all() -> Vec<AppSpec> {
    vec![
        spec(AppKind::App1),
        spec(AppKind::App2),
        spec(AppKind::App3),
        spec(AppKind::App4),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_compositions() {
        let a1 = spec(AppKind::App1);
        assert_eq!(a1.cr_variant, "cr_small");
        assert_eq!(a1.tl, TlKind::Wbfs);
        assert!(!a1.qf);

        let a2 = spec(AppKind::App2);
        assert_eq!(a2.cr_variant, "cr_large");
        assert!(a2.qf);
        assert!((a2.cr_cost - 1.63).abs() < 1e-9);

        let a3 = spec(AppKind::App3);
        assert_eq!(a3.fc_logic, "frame-rate");
        assert_eq!(a3.tl, TlKind::WbfsSpeed);

        let a4 = spec(AppKind::App4);
        assert_eq!(a4.va_variant, "cr_small"); // small re-id in VA
        assert_eq!(a4.tl, TlKind::Probabilistic);
    }

    #[test]
    fn apply_scales_service_model() {
        let mut cfg = ExperimentConfig::default();
        let base_cr = cfg.service.cr_alpha_ms + cfg.service.cr_beta_ms;
        spec(AppKind::App2).apply(&mut cfg, true);
        let new_cr = cfg.service.cr_alpha_ms + cfg.service.cr_beta_ms;
        assert!((new_cr / base_cr - 1.63).abs() < 1e-9);
        assert_eq!(cfg.tl, TlKind::Bfs);
    }

    #[test]
    fn apply_respects_tl_override() {
        let mut cfg = ExperimentConfig::default();
        cfg.tl = TlKind::Base;
        spec(AppKind::App1).apply(&mut cfg, false);
        assert_eq!(cfg.tl, TlKind::Base);
    }

    #[test]
    fn app3_is_vehicle_speed() {
        let mut cfg = ExperimentConfig::default();
        spec(AppKind::App3).apply(&mut cfg, true);
        assert!(cfg.workload.entity_speed_mps >= 8.0);
        assert!(cfg.tl_peak_speed_mps >= 14.0);
    }
}
