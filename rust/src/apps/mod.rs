//! Application composition: the §2.2 programming model made concrete.
//!
//! An application is five UDF blocks ([`crate::dataflow`] traits)
//! composed by [`AppBuilder`] into an [`AppDefinition`]. The engines
//! accept any `AppDefinition` — stock or user-built — and drive the
//! blocks only through the traits; *which* app is running never appears
//! in engine code.
//!
//! The Table-1 applications are ~25-line compositions over the
//! [`blocks`] library:
//!
//! | App | FC | VA | CR | TL | QF |
//! |-----|----|----|----|----|----|
//! | 1 | active-flag | HoG detector | re-id (small) | WBFS | — |
//! | 2 | active-flag | HoG detector | re-id (large) | BFS | RNN-fusion |
//! | 3 | frame-rate | YOLO detector | vehicle re-id | WBFS w/ speed | — |
//! | 4 | active-flag | re-id (small) | re-id (large) | Probabilistic | — |
//! | 5 | adaptive-rate | small detector | vehicle re-id | WBFS w/ speed | — |
//!
//! App 5 is ours, beyond the paper: a DeepScale-style adaptive
//! frame-rate FC (full rate while reacquiring, decimated in steady
//! state) over a vehicle re-id CR — and it exercises only the public
//! block API, the proof that a user can add App N without touching
//! engine code (see `examples/custom_app.rs` for an app built entirely
//! outside the crate).
//!
//! Model variants are typed ([`ModelVariant`]), so a composition that
//! names a nonexistent artifact fails at build time with a clear error
//! instead of a silent name mismatch inside the PJRT runtime.
//!
//! [`AppDefinition::apply`] configures an [`ExperimentConfig`] (cost
//! scaling, workload tuning, default TL) exactly like the figures in
//! §5 expect; [`resolve`] maps a config back to its stock composition
//! for the preset/CLI paths.

pub mod blocks;

pub use blocks::{
    ActiveFlagFc, AdaptiveRateFc, FrameRateFc, NoFusion, RnnFusion,
    SimDetector, SimReid,
};

use std::sync::Arc;

use crate::config::{AppKind, ExperimentConfig, TlKind};
use crate::coordinator::tl::stock_tl;
use crate::dataflow::{
    ContentionResolver, FilterControl, ModelVariant, QueryFusion, TlEnv,
    TlFactory, TrackingLogic, VideoAnalytics,
};

type FcFactory = Arc<dyn Fn() -> Box<dyn FilterControl> + Send + Sync>;
type VaFactory = Arc<dyn Fn() -> Box<dyn VideoAnalytics> + Send + Sync>;
type CrFactory =
    Arc<dyn Fn() -> Box<dyn ContentionResolver> + Send + Sync>;
type QfFactory = Arc<dyn Fn() -> Box<dyn QueryFusion> + Send + Sync>;

/// A composed tracking application: factories for the five blocks plus
/// the composition metadata the platform needs at configuration time
/// (cost model scaling, typed model variants, the Table-1 identity when
/// there is one). Engines mint block instances per worker / per query
/// through the `make_*` methods and never look inside them. In the
/// multi-query engines every query gets its **own** FC/VA/CR/QF/TL
/// instances minted from *its* app (see [`AppCatalog`]) — block state
/// never leaks across tenants.
#[derive(Clone)]
pub struct AppDefinition {
    pub name: String,
    pub description: String,
    /// Table-1 identity for stock compositions (`None` for user apps).
    pub kind: Option<AppKind>,
    /// Default TL strategy, when the TL is a stock spotlight; the §5
    /// experiments sweep `cfg.tl` independent of the app through this.
    pub default_tl: Option<TlKind>,
    /// AOT model the VA block executes on the live path.
    pub va_variant: ModelVariant,
    /// AOT model the CR block executes on the live path.
    pub cr_variant: ModelVariant,
    /// VA service-cost multiplier relative to App 1's profile.
    pub va_cost: f64,
    /// CR service-cost multiplier (the paper reports App 2 at ~1.63x).
    pub cr_cost: f64,
    /// Whether the QF block refines query embeddings.
    pub qf_enabled: bool,
    pub fc_label: &'static str,
    pub va_label: &'static str,
    pub cr_label: &'static str,
    pub qf_label: &'static str,
    pub tl_label: String,
    fc: FcFactory,
    va: VaFactory,
    cr: CrFactory,
    qf: QfFactory,
    tl: TlFactory,
}

impl AppDefinition {
    /// Mint a fresh FC block (one per engine / feed loop).
    pub fn make_fc(&self) -> Box<dyn FilterControl> {
        (self.fc)()
    }

    /// Mint a fresh VA block (one per executor worker).
    pub fn make_va(&self) -> Box<dyn VideoAnalytics> {
        (self.va)()
    }

    /// Mint a fresh CR block (one per executor worker).
    pub fn make_cr(&self) -> Box<dyn ContentionResolver> {
        (self.cr)()
    }

    /// Mint a fresh QF block (one per sink).
    pub fn make_qf(&self) -> Box<dyn QueryFusion> {
        (self.qf)()
    }

    /// Mint a fresh TL block (one per tracking query).
    pub fn make_tl(&self, env: &TlEnv<'_>) -> Box<dyn TrackingLogic> {
        (self.tl)(env)
    }

    /// Share of the TL factory (the service front builds per-query TLs
    /// from worker threads).
    pub fn tl_factory(&self) -> TlFactory {
        Arc::clone(&self.tl)
    }

    /// Replace the TL with the stock spotlight for `kind` — how the
    /// engines honor a config-level `cfg.tl` override.
    pub fn with_tl_kind(mut self, kind: TlKind) -> Self {
        self.default_tl = Some(kind);
        self.tl_label = format!("{kind:?}");
        self.tl = Arc::new(move |env: &TlEnv<'_>| stock_tl(kind, env));
        self
    }

    /// Configure an experiment for this application: per-stage
    /// service-cost scaling relative to App 1's profile, the FC block's
    /// workload tuning, and (when `override_tl`) the app's default
    /// tracking logic. Leaves `cfg.tl` alone otherwise — the §5
    /// experiments sweep TL independent of the app.
    pub fn apply(&self, cfg: &mut ExperimentConfig, override_tl: bool) {
        if let Some(kind) = self.kind {
            cfg.app = kind;
        }
        if override_tl {
            if let Some(tl) = self.default_tl {
                cfg.tl = tl;
            }
        }
        cfg.service.cr_alpha_ms *= self.cr_cost;
        cfg.service.cr_beta_ms *= self.cr_cost;
        cfg.service.va_alpha_ms *= self.va_cost;
        cfg.service.va_beta_ms *= self.va_cost;
        self.make_fc()
            .tune_workload(&mut cfg.workload, &mut cfg.tl_peak_speed_mps);
    }
}

/// Compose an [`AppDefinition`] from blocks. Unset blocks default to
/// App 1's calibration (active-flag FC, HoG detector, small re-id,
/// WBFS spotlight, no fusion).
///
/// Blocks are passed by value and must be `Clone` (the builder turns
/// them into factories so engines can mint per-worker / per-query
/// instances); non-`Clone` blocks plug in through the `*_with` factory
/// variants.
pub struct AppBuilder {
    name: String,
    description: String,
    kind: Option<AppKind>,
    fc: Option<FcFactory>,
    va: Option<VaFactory>,
    cr: Option<CrFactory>,
    qf: Option<QfFactory>,
    tl: Option<(TlFactory, Option<TlKind>, String)>,
}

impl AppBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            description: String::new(),
            kind: None,
            fc: None,
            va: None,
            cr: None,
            qf: None,
            tl: None,
        }
    }

    pub fn describe(mut self, text: impl Into<String>) -> Self {
        self.description = text.into();
        self
    }

    /// Claim a Table-1 identity (stock compositions only).
    pub fn table_kind(mut self, kind: AppKind) -> Self {
        self.kind = Some(kind);
        self
    }

    pub fn filter_control<B>(mut self, block: B) -> Self
    where
        B: FilterControl + Clone + 'static,
    {
        self.fc = Some(Arc::new(move || {
            Box::new(block.clone()) as Box<dyn FilterControl>
        }));
        self
    }

    pub fn filter_control_with<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> Box<dyn FilterControl> + Send + Sync + 'static,
    {
        self.fc = Some(Arc::new(factory));
        self
    }

    pub fn video_analytics<B>(mut self, block: B) -> Self
    where
        B: VideoAnalytics + Clone + 'static,
    {
        self.va = Some(Arc::new(move || {
            Box::new(block.clone()) as Box<dyn VideoAnalytics>
        }));
        self
    }

    pub fn video_analytics_with<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> Box<dyn VideoAnalytics> + Send + Sync + 'static,
    {
        self.va = Some(Arc::new(factory));
        self
    }

    pub fn contention_resolver<B>(mut self, block: B) -> Self
    where
        B: ContentionResolver + Clone + 'static,
    {
        self.cr = Some(Arc::new(move || {
            Box::new(block.clone()) as Box<dyn ContentionResolver>
        }));
        self
    }

    pub fn contention_resolver_with<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> Box<dyn ContentionResolver> + Send + Sync + 'static,
    {
        self.cr = Some(Arc::new(factory));
        self
    }

    pub fn query_fusion<B>(mut self, block: B) -> Self
    where
        B: QueryFusion + Clone + 'static,
    {
        self.qf = Some(Arc::new(move || {
            Box::new(block.clone()) as Box<dyn QueryFusion>
        }));
        self
    }

    pub fn query_fusion_with<F>(mut self, factory: F) -> Self
    where
        F: Fn() -> Box<dyn QueryFusion> + Send + Sync + 'static,
    {
        self.qf = Some(Arc::new(factory));
        self
    }

    /// Use the stock spotlight tracker with this strategy.
    pub fn tracking_logic(mut self, kind: TlKind) -> Self {
        self.tl = Some((
            Arc::new(move |env: &TlEnv<'_>| stock_tl(kind, env)),
            Some(kind),
            format!("{kind:?}"),
        ));
        self
    }

    /// Supply a custom TL factory (one instance minted per query).
    pub fn tracking_logic_with<F>(mut self, factory: F) -> Self
    where
        F: Fn(&TlEnv<'_>) -> Box<dyn TrackingLogic> + Send + Sync + 'static,
    {
        self.tl = Some((Arc::new(factory), None, "custom".into()));
        self
    }

    pub fn build(self) -> AppDefinition {
        let fc = self
            .fc
            .unwrap_or_else(|| {
                Arc::new(|| Box::new(ActiveFlagFc) as Box<dyn FilterControl>)
            });
        let va = self
            .va
            .unwrap_or_else(|| {
                Arc::new(|| {
                    Box::new(SimDetector::hog()) as Box<dyn VideoAnalytics>
                })
            });
        let cr = self
            .cr
            .unwrap_or_else(|| {
                Arc::new(|| {
                    Box::new(SimReid::small()) as Box<dyn ContentionResolver>
                })
            });
        let qf = self
            .qf
            .unwrap_or_else(|| {
                Arc::new(|| Box::new(NoFusion) as Box<dyn QueryFusion>)
            });
        let (tl, default_tl, tl_label) = self.tl.unwrap_or_else(|| {
            (
                Arc::new(|env: &TlEnv<'_>| stock_tl(TlKind::Wbfs, env))
                    as TlFactory,
                Some(TlKind::Wbfs),
                format!("{:?}", TlKind::Wbfs),
            )
        });
        // Cache the composition metadata off one minted instance each,
        // so reports and the live engines never re-mint just to ask.
        let (va_variant, va_cost, va_label) = {
            let b = va();
            (b.variant(), b.cost(), b.label())
        };
        let (cr_variant, cr_cost, cr_label) = {
            let b = cr();
            (b.variant(), b.cost(), b.label())
        };
        let (qf_enabled, qf_label) = {
            let b = qf();
            (b.fuses(), b.label())
        };
        let fc_label = fc().label();
        AppDefinition {
            name: self.name,
            description: self.description,
            kind: self.kind,
            default_tl,
            va_variant,
            cr_variant,
            va_cost,
            cr_cost,
            qf_enabled,
            fc_label,
            va_label,
            cr_label,
            qf_label,
            tl_label,
            fc,
            va,
            cr,
            qf,
            tl,
        }
    }
}

/// App 1 — missing-person tracking: HoG VA, OpenReid-class CR,
/// weighted-BFS spotlight.
pub fn app1() -> AppDefinition {
    AppBuilder::new("App1-person")
        .describe(
            "Missing-person tracking: HoG VA, OpenReid-class CR, \
             weighted-BFS spotlight.",
        )
        .table_kind(AppKind::App1)
        .filter_control(ActiveFlagFc)
        .video_analytics(SimDetector::hog())
        .contention_resolver(SimReid::small())
        .tracking_logic(TlKind::Wbfs)
        .build()
}

/// App 2 — person tracking with the deeper CR DNN and RNN-style query
/// fusion.
pub fn app2() -> AppDefinition {
    AppBuilder::new("App2-person-fusion")
        .describe(
            "Person tracking with a deeper CR DNN and RNN-style query \
             fusion.",
        )
        .table_kind(AppKind::App2)
        .filter_control(ActiveFlagFc)
        .video_analytics(SimDetector::hog())
        .contention_resolver(SimReid::large())
        .tracking_logic(TlKind::Bfs)
        .query_fusion(RnnFusion::default())
        .build()
}

/// App 3 — stolen-vehicle tracking: YOLO-class VA, BoxCars CR,
/// speed-aware WBFS with FC frame-rate control.
pub fn app3() -> AppDefinition {
    AppBuilder::new("App3-vehicle")
        .describe(
            "Stolen-vehicle tracking: YOLO-class VA, BoxCars CR, \
             speed-aware WBFS with FC frame-rate control.",
        )
        .table_kind(AppKind::App3)
        .filter_control(FrameRateFc::vehicle())
        .video_analytics(SimDetector::yolo())
        .contention_resolver(SimReid::vehicle())
        .tracking_logic(TlKind::WbfsSpeed)
        .build()
}

/// App 4 — two-stage re-id (small model in VA, large in CR) with
/// Naive-Bayes path-likelihood TL.
pub fn app4() -> AppDefinition {
    AppBuilder::new("App4-two-stage")
        .describe(
            "Two-stage re-id (small model in VA, large in CR) with \
             Naive-Bayes path-likelihood TL.",
        )
        .table_kind(AppKind::App4)
        .filter_control(ActiveFlagFc)
        .video_analytics(SimDetector::reid_small())
        .contention_resolver(SimReid::large())
        .tracking_logic(TlKind::Probabilistic)
        .build()
}

/// App 5 — ours, beyond the paper: DeepScale-style adaptive frame-rate
/// FC (full rate while reacquiring, 1-in-4 frames in steady state) over
/// a cheap small-input detector and a vehicle re-id CR, with the
/// speed-adaptive spotlight. Composed purely from the public block API.
pub fn app5() -> AppDefinition {
    AppBuilder::new("App5-adaptive-vehicle")
        .describe(
            "Adaptive-rate vehicle tracking (DeepScale-style): full \
             frame rate during reacquisition, decimated steady state, \
             small-input detector, vehicle re-id CR.",
        )
        .filter_control(AdaptiveRateFc::new(4, 3))
        .video_analytics(
            SimDetector::new(ModelVariant::Va)
                .with_cost(0.6)
                .labeled("detector-small"),
        )
        .contention_resolver(SimReid::vehicle())
        .tracking_logic(TlKind::WbfsSpeed)
        .build()
}

/// Table-1 composition for a config-level application kind.
pub fn table1(kind: AppKind) -> AppDefinition {
    match kind {
        AppKind::App1 => app1(),
        AppKind::App2 => app2(),
        AppKind::App3 => app3(),
        AppKind::App4 => app4(),
    }
}

/// The stock composition a config describes: the Table-1 app for
/// `cfg.app`, tracking with the spotlight `cfg.tl` selects (the config
/// keeps TL authority so the §5 sweeps work unchanged). Custom apps
/// skip this entirely and hand their [`AppDefinition`] to
/// [`crate::coordinator::des::run_app`] (or the other engines'
/// `with_app` constructors).
pub fn resolve(cfg: &ExperimentConfig) -> AppDefinition {
    table1(cfg.app).with_tl_kind(cfg.tl)
}

/// All stock app definitions: the four Table-1 apps plus App 5.
pub fn all() -> Vec<AppDefinition> {
    vec![app1(), app2(), app3(), app4(), app5()]
}

/// Per-kind application catalog for the multi-query engines: resolves
/// each query's [`crate::service::QuerySpec::app`] to the
/// [`AppDefinition`] whose blocks that query runs, so concurrent
/// queries can run *different* compositions over the shared workers.
///
/// The engine-level default app (possibly a custom composition handed
/// to `with_app`/`start_with_app`) serves queries naming its kind — a
/// custom app with no Table-1 identity is registered under the config's
/// `cfg.app` kind. Every other kind resolves to its stock Table-1
/// composition with the config's TL override (the config keeps TL
/// authority, exactly like [`resolve`]).
pub struct AppCatalog {
    default_kind: AppKind,
    apps: [Arc<AppDefinition>; 4],
}

impl AppCatalog {
    /// Build the catalog. `fallback_kind`/`tl` come from the engine
    /// config (`cfg.app`, `cfg.tl`).
    pub fn new(
        default_app: AppDefinition,
        fallback_kind: AppKind,
        tl: TlKind,
    ) -> Self {
        let default_kind = default_app.kind.unwrap_or(fallback_kind);
        let default_app = Arc::new(default_app);
        let mk = |kind: AppKind| -> Arc<AppDefinition> {
            if kind == default_kind {
                Arc::clone(&default_app)
            } else {
                Arc::new(table1(kind).with_tl_kind(tl))
            }
        };
        Self {
            default_kind,
            apps: [
                mk(AppKind::App1),
                mk(AppKind::App2),
                mk(AppKind::App3),
                mk(AppKind::App4),
            ],
        }
    }

    fn idx(kind: AppKind) -> usize {
        kind.index()
    }

    /// The application a query of `kind` runs.
    pub fn get(&self, kind: AppKind) -> &Arc<AppDefinition> {
        &self.apps[Self::idx(kind)]
    }

    /// The engine-level default application.
    pub fn default_app(&self) -> &Arc<AppDefinition> {
        &self.apps[Self::idx(self.default_kind)]
    }

    /// The kind the default application is registered under.
    pub fn default_kind(&self) -> AppKind {
        self.default_kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_compositions() {
        let a1 = app1();
        assert_eq!(a1.cr_variant, ModelVariant::CrSmall);
        assert_eq!(a1.default_tl, Some(TlKind::Wbfs));
        assert!(!a1.qf_enabled);
        assert_eq!(a1.kind, Some(AppKind::App1));

        let a2 = app2();
        assert_eq!(a2.cr_variant, ModelVariant::CrLarge);
        assert!(a2.qf_enabled);
        assert!((a2.cr_cost - 1.63).abs() < 1e-9);
        assert_eq!(a2.default_tl, Some(TlKind::Bfs));

        let a3 = app3();
        assert_eq!(a3.fc_label, "frame-rate");
        assert_eq!(a3.default_tl, Some(TlKind::WbfsSpeed));
        assert!((a3.va_cost - 2.5).abs() < 1e-9);

        let a4 = app4();
        assert_eq!(a4.va_variant, ModelVariant::CrSmall); // small re-id in VA
        assert_eq!(a4.default_tl, Some(TlKind::Probabilistic));
        assert!((a4.va_cost - 3.0).abs() < 1e-9);
    }

    #[test]
    fn app5_is_a_public_api_composition() {
        let a5 = app5();
        assert_eq!(a5.kind, None, "App 5 is beyond Table 1");
        assert_eq!(a5.fc_label, "adaptive-rate");
        assert_eq!(a5.cr_variant, ModelVariant::CrSmall);
        assert!((a5.va_cost - 0.6).abs() < 1e-9);
        assert_eq!(a5.default_tl, Some(TlKind::WbfsSpeed));
    }

    #[test]
    fn apply_scales_service_model() {
        let mut cfg = ExperimentConfig::default();
        let base_cr = cfg.service.cr_alpha_ms + cfg.service.cr_beta_ms;
        app2().apply(&mut cfg, true);
        let new_cr = cfg.service.cr_alpha_ms + cfg.service.cr_beta_ms;
        assert!((new_cr / base_cr - 1.63).abs() < 1e-9);
        assert_eq!(cfg.tl, TlKind::Bfs);
        assert_eq!(cfg.app, AppKind::App2);
    }

    #[test]
    fn apply_respects_tl_override() {
        let mut cfg = ExperimentConfig::default();
        cfg.tl = TlKind::Base;
        app1().apply(&mut cfg, false);
        assert_eq!(cfg.tl, TlKind::Base);
    }

    #[test]
    fn app3_is_vehicle_speed() {
        let mut cfg = ExperimentConfig::default();
        app3().apply(&mut cfg, true);
        assert!(cfg.workload.entity_speed_mps >= 8.0);
        assert!(cfg.tl_peak_speed_mps >= 14.0);
    }

    #[test]
    fn builder_defaults_are_app1_calibration() {
        let app = AppBuilder::new("bare").build();
        assert_eq!(app.fc_label, "active-flag");
        assert_eq!(app.va_variant, ModelVariant::Va);
        assert_eq!(app.cr_variant, ModelVariant::CrSmall);
        assert!((app.va_cost - 1.0).abs() < 1e-9);
        assert!((app.cr_cost - 1.0).abs() < 1e-9);
        assert!(!app.qf_enabled);
        assert_eq!(app.default_tl, Some(TlKind::Wbfs));
    }

    #[test]
    fn factories_mint_independent_instances() {
        use crate::config::WorkloadConfig;
        use crate::roadnet::{generate, place_cameras};

        let app = app1();
        let g = generate(&WorkloadConfig::default(), 5);
        let cams = place_cameras(&g, 100, 0, 40.0);
        let env = TlEnv {
            peak_speed_mps: 4.0,
            mean_road_m: 84.5,
            fov_m: 40.0,
            cameras: &cams,
        };
        let mut tl_a = app.make_tl(&env);
        let mut tl_b = app.make_tl(&env);
        tl_a.on_detection(3, 1_000_000, true);
        // Independent state: only tl_a has a sighting.
        assert!(tl_a.last_seen().is_some());
        assert!(tl_b.last_seen().is_none());
        let mut out = Vec::new();
        tl_b.active_set_into(&g, 2_000_000, &mut out);
        assert_eq!(out.len(), 100, "tl_b still bootstraps all-active");
    }

    #[test]
    fn catalog_resolves_per_query_apps() {
        // Stock default: its kind's slot is the default app itself.
        let cat =
            AppCatalog::new(app2(), AppKind::App1, TlKind::Wbfs);
        assert_eq!(cat.default_kind(), AppKind::App2);
        assert!(cat.get(AppKind::App2).qf_enabled);
        assert_eq!(cat.get(AppKind::App2).name, "App2-person-fusion");
        // Other kinds resolve to stock compositions with the config TL.
        assert_eq!(cat.get(AppKind::App3).fc_label, "frame-rate");
        assert_eq!(
            cat.get(AppKind::App3).default_tl,
            Some(TlKind::Wbfs),
            "config keeps TL authority over non-default apps"
        );
        // A custom app (no Table-1 identity) registers under the
        // config's kind.
        let custom = AppBuilder::new("custom").build();
        let cat =
            AppCatalog::new(custom, AppKind::App4, TlKind::Bfs);
        assert_eq!(cat.default_kind(), AppKind::App4);
        assert_eq!(cat.get(AppKind::App4).name, "custom");
        assert_eq!(cat.default_app().name, "custom");
        assert_eq!(cat.get(AppKind::App1).name, "App1-person");
    }

    #[test]
    fn with_tl_kind_overrides_the_spotlight() {
        let app = app1().with_tl_kind(TlKind::Base);
        assert_eq!(app.default_tl, Some(TlKind::Base));
        use crate::config::WorkloadConfig;
        use crate::roadnet::{generate, place_cameras};
        let g = generate(&WorkloadConfig::default(), 5);
        let cams = place_cameras(&g, 50, 0, 40.0);
        let env = TlEnv {
            peak_speed_mps: 4.0,
            mean_road_m: 84.5,
            fov_m: 40.0,
            cameras: &cams,
        };
        let mut tl = app.make_tl(&env);
        tl.on_detection(0, 1, true);
        let mut out = Vec::new();
        tl.active_set_into(&g, 10, &mut out);
        assert_eq!(out.len(), 50, "Base keeps everything active");
    }
}
