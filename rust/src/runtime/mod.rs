//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! DESIGN.md and /opt/xla-example/README.md for why text, not proto) and
//! the model weights arrive through `weights.bin`, uploaded once as
//! device buffers.
//!
//! The PJRT-backed [`ModelPool`] is gated behind the `pjrt` cargo
//! feature. Without it, an API-identical stub is compiled whose `load`
//! reports a clear error — so the library, the DES engine and the whole
//! service layer build and test green on machines without PJRT
//! artifacts or bindings.

mod manifest;
#[cfg(feature = "pjrt")]
mod pool;
#[cfg(not(feature = "pjrt"))]
#[path = "pool_stub.rs"]
mod pool;

pub use manifest::{default_dir, Manifest, VariantSpec, WeightEntry};
pub use pool::{ModelOutput, ModelPool};
