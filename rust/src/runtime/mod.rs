//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the interchange is HLO *text* (see
//! DESIGN.md and /opt/xla-example/README.md for why text, not proto) and
//! the model weights arrive through `weights.bin`, uploaded once as
//! device buffers.

mod manifest;
mod pool;

pub use manifest::{default_dir, Manifest, VariantSpec, WeightEntry};
pub use pool::{ModelOutput, ModelPool};
