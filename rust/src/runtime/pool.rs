//! Model pool: one compiled PJRT executable per (variant, batch bucket).
//!
//! The dynamic batcher picks an arbitrary batch size; the pool pads the
//! batch up to the nearest compiled bucket, executes, and slices the
//! outputs back. Weights are uploaded to device buffers once at load
//! time (`execute_b`), so the steady-state request path transfers only
//! the image batch and the query embedding.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;
use crate::tuning::XiModel;
use crate::util::Micros;

/// Scores + embeddings for an executed batch.
#[derive(Debug, Clone)]
pub struct ModelOutput {
    /// Cosine-similarity score per input frame.
    pub scores: Vec<f32>,
    /// `feat_dim`-dim embedding per input frame (row-major).
    pub embeddings: Vec<f32>,
}

struct LoadedVariant {
    /// bucket -> compiled executable.
    exes: HashMap<usize, xla::PjRtLoadedExecutable>,
    /// Weight device buffers in parameter order.
    weights: Vec<xla::PjRtBuffer>,
}

/// All loaded model variants plus the PJRT client.
pub struct ModelPool {
    client: xla::PjRtClient,
    manifest: Manifest,
    variants: HashMap<String, LoadedVariant>,
    /// Reusable bucket-padding buffer: executing a batch smaller than
    /// its bucket used to allocate `bucket × img_dim` floats per call.
    /// (The pool is single-threaded — the client is not `Send` — so a
    /// `RefCell` suffices.)
    pad_scratch: RefCell<Vec<f32>>,
}

impl ModelPool {
    /// Load selected variants (pass e.g. `&["va", "cr_small"]`) at the
    /// given buckets (`None` = all manifest buckets).
    pub fn load(
        dir: &Path,
        variant_names: &[&str],
        buckets: Option<&[usize]>,
    ) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        let mut variants = HashMap::new();
        for &name in variant_names {
            let spec = manifest
                .variants
                .get(name)
                .ok_or_else(|| anyhow!("unknown variant {name}"))?
                .clone();
            let use_buckets: Vec<usize> = match buckets {
                Some(bs) => bs.to_vec(),
                None => manifest.buckets.clone(),
            };
            let mut exes = HashMap::new();
            for b in use_buckets {
                let path = manifest
                    .hlo_path(name, b)
                    .ok_or_else(|| anyhow!("{name} missing bucket {b}"))?;
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("{e:?}"))
                    .with_context(|| format!("parsing {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("{e:?}"))
                    .with_context(|| format!("compiling {name} b{b}"))?;
                exes.insert(b, exe);
            }
            // Upload weights once.
            let mut wbufs = Vec::new();
            for wname in &spec.weights {
                let (entry, data) = manifest
                    .tensor(wname)
                    .ok_or_else(|| anyhow!("missing tensor {wname}"))?;
                let buf = client
                    .buffer_from_host_buffer::<f32>(
                        data,
                        &entry.shape,
                        None,
                    )
                    .map_err(|e| anyhow!("{e:?}"))?;
                wbufs.push(buf);
            }
            variants.insert(
                name.to_string(),
                LoadedVariant {
                    exes,
                    weights: wbufs,
                },
            );
        }
        Ok(Self {
            client,
            manifest,
            variants,
            pad_scratch: RefCell::new(Vec::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn img_dim(&self) -> usize {
        self.manifest.img_dim
    }

    pub fn feat_dim(&self) -> usize {
        self.manifest.feat_dim
    }

    /// Buckets actually loaded for a variant (sorted).
    pub fn loaded_buckets(&self, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .variants
            .get(variant)
            .map(|lv| lv.exes.keys().copied().collect())
            .unwrap_or_default();
        v.sort_unstable();
        v
    }

    fn bucket_for(&self, variant: &str, batch: usize) -> Result<usize> {
        let loaded = self.loaded_buckets(variant);
        loaded
            .iter()
            .copied()
            .find(|&b| b >= batch)
            .or_else(|| loaded.last().copied())
            .ok_or_else(|| anyhow!("no buckets loaded for {variant}"))
    }

    /// Run a re-id variant on `batch` frames (each `img_dim` floats)
    /// against `query` (a `feat_dim` embedding; all-zero disables the
    /// score head). Pads to the nearest bucket and slices back.
    pub fn execute(
        &self,
        variant: &str,
        images: &[f32],
        query: &[f32],
    ) -> Result<ModelOutput> {
        let d = self.manifest.img_dim;
        anyhow::ensure!(
            images.len() % d == 0,
            "images not a multiple of img_dim"
        );
        let batch = images.len() / d;
        anyhow::ensure!(batch > 0, "empty batch");
        anyhow::ensure!(
            query.len() == self.manifest.feat_dim,
            "bad query len {}",
            query.len()
        );
        let bucket = self.bucket_for(variant, batch)?;
        let lv = &self.variants[variant];
        let exe = lv
            .exes
            .get(&bucket)
            .ok_or_else(|| anyhow!("{variant} bucket {bucket}"))?;

        // Pad the image batch up to the bucket, reusing the pool's
        // scratch buffer instead of allocating `bucket × d` floats per
        // padded execution.
        let mut pad = self.pad_scratch.borrow_mut();
        let img_data: &[f32] = if batch == bucket {
            images
        } else {
            pad.clear();
            pad.resize(bucket * d, 0.0);
            pad[..images.len()].copy_from_slice(images);
            &pad[..]
        };
        let img_buf = self
            .client
            .buffer_from_host_buffer::<f32>(img_data, &[bucket, d], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let q_buf = self
            .client
            .buffer_from_host_buffer::<f32>(
                query,
                &[self.manifest.feat_dim],
                None,
            )
            .map_err(|e| anyhow!("{e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = vec![&img_buf, &q_buf];
        args.extend(lv.weights.iter());
        let result = exe.execute_b(&args).map_err(|e| anyhow!("{e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (scores_l, embs_l) =
            lit.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        let mut scores =
            scores_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let mut embeddings =
            embs_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        scores.truncate(batch);
        embeddings.truncate(batch * self.manifest.feat_dim);
        Ok(ModelOutput {
            scores,
            embeddings,
        })
    }

    /// Bootstrap a query embedding from a query *image* using the same
    /// executable (zero query disables the score head; §model.py).
    pub fn embed_query(&self, variant: &str, image: &[f32]) -> Result<Vec<f32>> {
        let zero_q = vec![0f32; self.manifest.feat_dim];
        let out = self.execute(variant, image, &zero_q)?;
        Ok(out.embeddings)
    }

    /// Time each loaded bucket of a variant to calibrate ξ(b) — the
    /// measured analogue of the paper's service model.
    pub fn calibrate_xi(
        &self,
        variant: &str,
        reps: usize,
    ) -> Result<(XiModel, Vec<(usize, Micros)>)> {
        let d = self.manifest.img_dim;
        let q = vec![0f32; self.manifest.feat_dim];
        let mut samples = Vec::new();
        for b in self.loaded_buckets(variant) {
            let images = vec![0.5f32; b * d];
            // Warm-up once, then measure.
            self.execute(variant, &images, &q)?;
            let start = Instant::now();
            for _ in 0..reps.max(1) {
                self.execute(variant, &images, &q)?;
            }
            let per = start.elapsed().as_micros() as Micros
                / reps.max(1) as Micros;
            samples.push((b, per));
        }
        Ok((XiModel::from_samples(&samples), samples))
    }
}
