//! `artifacts/manifest.json` — the contract between the Python AOT
//! export and the Rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// One exported model variant (va / cr_small / cr_large / qf).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    /// batch bucket -> HLO text file name.
    pub files: HashMap<usize, String>,
    /// Weight tensor names, in parameter order after (images, query).
    pub weights: Vec<String>,
}

/// One tensor inside `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset and length in f32 elements.
    pub offset: usize,
    pub len: usize,
}

/// Parsed manifest plus the weight blob.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub img_dim: usize,
    pub feat_dim: usize,
    pub buckets: Vec<usize>,
    pub variants: HashMap<String, VariantSpec>,
    pub weight_entries: Vec<WeightEntry>,
    /// The full weights.bin contents as f32.
    pub weights: Vec<f32>,
}

impl Manifest {
    /// Load `manifest.json` + `weights.bin` from the artifacts dir.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts`",
                    dir.display()
                )
            })?;
        let j = Json::parse(&text).map_err(|e| anyhow!(e))?;

        let img_dim = j
            .at("img_dim")
            .as_usize()
            .ok_or_else(|| anyhow!("img_dim"))?;
        let feat_dim = j
            .at("feat_dim")
            .as_usize()
            .ok_or_else(|| anyhow!("feat_dim"))?;
        let buckets: Vec<usize> = j
            .at("buckets")
            .as_arr()
            .ok_or_else(|| anyhow!("buckets"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();

        let mut variants = HashMap::new();
        for (name, spec) in j
            .at("variants")
            .as_obj()
            .ok_or_else(|| anyhow!("variants"))?
        {
            let files = spec
                .at("files")
                .as_obj()
                .ok_or_else(|| anyhow!("files"))?
                .iter()
                .map(|(b, f)| {
                    Ok((
                        b.parse::<usize>()?,
                        f.as_str()
                            .ok_or_else(|| anyhow!("file name"))?
                            .to_string(),
                    ))
                })
                .collect::<Result<HashMap<_, _>>>()?;
            let weights = spec
                .at("weights")
                .as_arr()
                .ok_or_else(|| anyhow!("weights"))?
                .iter()
                .filter_map(Json::as_str)
                .map(String::from)
                .collect();
            variants.insert(name.clone(), VariantSpec { files, weights });
        }

        let wspec = j.at("weights");
        let weight_entries = wspec
            .at("entries")
            .as_arr()
            .ok_or_else(|| anyhow!("weight entries"))?
            .iter()
            .map(|e| {
                Ok(WeightEntry {
                    name: e
                        .at("name")
                        .as_str()
                        .ok_or_else(|| anyhow!("entry name"))?
                        .to_string(),
                    shape: e
                        .at("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    offset: e
                        .at("offset")
                        .as_usize()
                        .ok_or_else(|| anyhow!("offset"))?,
                    len: e
                        .at("len")
                        .as_usize()
                        .ok_or_else(|| anyhow!("len"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let wfile = wspec
            .at("file")
            .as_str()
            .ok_or_else(|| anyhow!("weights file"))?;
        let bytes = std::fs::read(dir.join(wfile))
            .with_context(|| format!("reading {wfile}"))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "weights.bin not a multiple of 4 bytes"
        );
        let weights: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let total: usize = weight_entries.iter().map(|e| e.len).sum();
        anyhow::ensure!(
            weights.len() == total,
            "weights.bin has {} f32s, manifest expects {total}",
            weights.len()
        );

        Ok(Self {
            dir: dir.to_path_buf(),
            img_dim,
            feat_dim,
            buckets,
            variants,
            weight_entries,
            weights,
        })
    }

    /// Slice of the blob for a named tensor.
    pub fn tensor(&self, name: &str) -> Option<(&WeightEntry, &[f32])> {
        let e = self.weight_entries.iter().find(|e| e.name == name)?;
        Some((e, &self.weights[e.offset..e.offset + e.len]))
    }

    /// Smallest bucket >= `batch` (or the largest bucket if none fits).
    pub fn bucket_for(&self, batch: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= batch)
            .min()
            .unwrap_or_else(|| {
                self.buckets.iter().copied().max().unwrap_or(1)
            })
    }

    /// Path to a variant's HLO file at a bucket.
    pub fn hlo_path(&self, variant: &str, bucket: usize) -> Option<PathBuf> {
        Some(self.dir.join(self.variants.get(variant)?.files.get(&bucket)?))
    }
}

/// Default artifacts directory (repo-root/artifacts).
pub fn default_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load() -> Option<Manifest> {
        Manifest::load(&default_dir()).ok()
    }

    #[test]
    fn loads_real_manifest() {
        // Skips silently if artifacts haven't been built (unit-test runs
        // before `make artifacts`); integration tests require them.
        let Some(m) = load() else { return };
        assert_eq!(m.img_dim, 8192);
        assert_eq!(m.feat_dim, 128);
        assert!(m.buckets.contains(&25));
        for v in ["va", "cr_small", "cr_large", "qf"] {
            assert!(m.variants.contains_key(v), "missing {v}");
        }
    }

    #[test]
    fn bucket_rounding() {
        let Some(m) = load() else { return };
        // buckets: 1,2,4,8,16,25,32
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(3), 4);
        assert_eq!(m.bucket_for(17), 25);
        assert_eq!(m.bucket_for(26), 32);
        assert_eq!(m.bucket_for(99), 32); // capped at the largest
    }

    #[test]
    fn tensors_resolve() {
        let Some(m) = load() else { return };
        let spec = &m.variants["va"];
        for name in &spec.weights {
            let (e, data) = m.tensor(name).expect("tensor present");
            assert_eq!(
                data.len(),
                e.shape.iter().product::<usize>(),
                "shape/len mismatch for {name}"
            );
        }
    }

    #[test]
    fn hlo_files_exist() {
        let Some(m) = load() else { return };
        for (v, spec) in &m.variants {
            for &b in spec.files.keys() {
                let p = m.hlo_path(v, b).unwrap();
                assert!(p.exists(), "{v} bucket {b}: {p:?}");
            }
        }
    }
}
