//! API-identical stand-in for the PJRT-backed [`ModelPool`] used when
//! the `pjrt` feature is disabled.
//!
//! `load` always fails with an actionable message; the remaining
//! methods exist so call sites (live engine, benches, examples)
//! type-check identically under both feature sets.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::manifest::Manifest;
use crate::tuning::XiModel;
use crate::util::Micros;

/// Scores + embeddings for an executed batch.
#[derive(Debug, Clone)]
pub struct ModelOutput {
    /// Cosine-similarity score per input frame.
    pub scores: Vec<f32>,
    /// `feat_dim`-dim embedding per input frame (row-major).
    pub embeddings: Vec<f32>,
}

/// Stub model pool: never constructible without the `pjrt` feature.
pub struct ModelPool {
    manifest: Manifest,
}

impl ModelPool {
    pub fn load(
        _dir: &Path,
        _variant_names: &[&str],
        _buckets: Option<&[usize]>,
    ) -> Result<Self> {
        Err(anyhow!(
            "anveshak was built without the `pjrt` feature: model \
             execution is unavailable (rebuild with `--features pjrt` \
             on a machine with the PJRT toolchain and artifacts)"
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn img_dim(&self) -> usize {
        self.manifest.img_dim
    }

    pub fn feat_dim(&self) -> usize {
        self.manifest.feat_dim
    }

    /// Buckets actually loaded for a variant (sorted).
    pub fn loaded_buckets(&self, _variant: &str) -> Vec<usize> {
        Vec::new()
    }

    pub fn execute(
        &self,
        _variant: &str,
        _images: &[f32],
        _query: &[f32],
    ) -> Result<ModelOutput> {
        Err(anyhow!("pjrt feature disabled"))
    }

    pub fn embed_query(
        &self,
        _variant: &str,
        _image: &[f32],
    ) -> Result<Vec<f32>> {
        Err(anyhow!("pjrt feature disabled"))
    }

    pub fn calibrate_xi(
        &self,
        _variant: &str,
        _reps: usize,
    ) -> Result<(XiModel, Vec<(usize, Micros)>)> {
        Err(anyhow!("pjrt feature disabled"))
    }
}
