//! `HashMap`/`HashSet` with a multiply-xor hasher for integer keys.
//!
//! The engine hot paths keyed by dense-ish integers — per-event budget
//! records and sink batch tracking in both DES engines, the
//! per-(task, query) budget tables of the multi-query engine, the TL's
//! vertex→camera lookup hit once per spotlight vertex, the road
//! generator's edge-dedup set, and the identity-embedding cache — would
//! all be dominated by std's SipHash. This is the same idea as
//! `rustc-hash`'s FxHasher, implemented locally because the build is
//! offline. (The per-event outcome ledger is *not* a map: source event
//! ids are dense, so it indexes a flat `Vec` directly.)

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher for integer-ish keys (not DoS-resistant — only
/// used for internal, non-adversarial keys).
#[derive(Default)]
pub struct FastHasher {
    state: u64,
}

const K: u64 = 0x517C_C1B7_2722_0A95;

impl Hasher for FastHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state.rotate_left(5) ^ b as u64)
                .wrapping_mul(K);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.state = (self.state.rotate_left(5) ^ n).wrapping_mul(K);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.write_u64(n as u64);
    }
}

/// Drop-in `HashMap` with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FastHasher>>;

/// Drop-in `HashSet` with the fast hasher (e.g. the road generator's
/// O(1) edge-dedup set).
pub type FastSet<K> = HashSet<K, BuildHasherDefault<FastHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaves_like_a_map() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for k in 0..1000u64 {
            m.insert(k, (k * 3) as u32);
        }
        for k in 0..1000u64 {
            assert_eq!(m.get(&k), Some(&((k * 3) as u32)));
        }
        assert_eq!(m.len(), 1000);
        m.remove(&500);
        assert!(!m.contains_key(&500));
    }

    #[test]
    fn hashes_spread() {
        // Dense keys must not collide into few buckets: sanity-check
        // the low bits vary.
        use std::hash::Hash;
        let mut low = std::collections::HashSet::new();
        for k in 0..256u64 {
            let mut h = FastHasher::default();
            k.hash(&mut h);
            low.insert(h.finish() & 0xFF);
        }
        assert!(low.len() > 100, "only {} distinct low bytes", low.len());
    }
}
