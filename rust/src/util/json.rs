//! Minimal JSON parser/writer (offline substitute for `serde_json`).
//!
//! Used to read the AOT `artifacts/manifest.json` and to write
//! figure-data files for the experiment harness. Supports the full JSON
//! grammar except exotic number forms; numbers parse as `f64` (adequate:
//! the manifest carries shapes, offsets and names only).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a readable message if
    /// the path is missing (manifest files are trusted build outputs).
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- parsing -------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid UTF-8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#)
            .unwrap();
        assert_eq!(v.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at("a").as_arr().unwrap()[2].at("b").as_str(),
            Some("x")
        );
        assert_eq!(v.at("c"), &Json::Null);
    }

    #[test]
    fn round_trips() {
        let src = r#"{"nums":[1,2.5,-3],"s":"he\"llo","t":true,"n":null,"o":{"x":0}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // The actual AOT output must parse if present (CI runs after
        // `make artifacts`).
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        );
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.at("img_dim").as_usize(), Some(8192));
            assert!(m.at("variants").get("va").is_some());
        }
    }

    #[test]
    fn obj_builder() {
        let v = obj([("a", 1usize.into()), ("b", "x".into())]);
        assert_eq!(v.to_string(), r#"{"a":1,"b":"x"}"#);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }
}
