//! Small shared utilities: virtual time, seeded RNG helpers, statistics,
//! and the hand-rolled JSON codec (the build environment is offline, so
//! `rand`/`serde_json` substitutes live here — see DESIGN.md).

pub mod fastmap;
pub mod json;
pub mod rng;

pub use fastmap::{FastMap, FastSet};
pub use json::Json;
pub use rng::Rng;

/// Virtual (or wall) time in microseconds. All tuning math in the paper
/// operates on timestamps; `i64` µs gives ±292k years of range and exact
/// arithmetic for budget comparisons.
pub type Micros = i64;

/// One second in [`Micros`].
pub const SEC: Micros = 1_000_000;
/// One millisecond in [`Micros`].
pub const MS: Micros = 1_000;

/// Convert seconds (f64) to [`Micros`].
pub fn secs(s: f64) -> Micros {
    (s * SEC as f64).round() as Micros
}

/// Convert milliseconds (f64) to [`Micros`].
pub fn millis(ms: f64) -> Micros {
    (ms * MS as f64).round() as Micros
}

/// Convert [`Micros`] to f64 seconds (for reporting).
pub fn to_secs(t: Micros) -> f64 {
    t as f64 / SEC as f64
}

/// Build a deterministic [`Rng`] from a base seed and a subsystem salt,
/// so experiment runs are exactly reproducible per subsystem.
pub fn rng(seed: u64, salt: u64) -> Rng {
    Rng::seed_from_u64(seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Percentile of a sorted slice (linear interpolation), `p` in `[0, 100]`.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Summary stats over an unsorted sample.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stats {
    pub count: usize,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p99: f64,
    pub max: f64,
    pub mean: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        Stats {
            count: xs.len(),
            min: xs[0],
            p25: percentile(&xs, 25.0),
            median: percentile(&xs, 50.0),
            p75: percentile(&xs, 75.0),
            p99: percentile(&xs, 99.0),
            max: *xs.last().unwrap(),
            mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_round_trip() {
        assert_eq!(secs(15.0), 15 * SEC);
        assert_eq!(millis(120.0), 120 * MS);
        assert!((to_secs(secs(3.25)) - 3.25).abs() < 1e-9);
    }

    #[test]
    fn rng_is_deterministic_per_salt() {
        let mut a = rng(7, 1);
        let mut b = rng(7, 1);
        let mut c = rng(7, 2);
        assert_eq!(a.next_u64(), b.next_u64());
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn stats_basic() {
        let s = Stats::from(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.median - 2.0).abs() < 1e-12);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_empty_is_default() {
        let s = Stats::from(vec![]);
        assert_eq!(s.count, 0);
    }
}
