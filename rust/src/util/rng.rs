//! Deterministic PRNG — xoshiro256** seeded via SplitMix64.
//!
//! The offline build environment has no `rand` crate, so the platform
//! ships its own small generator. xoshiro256** is the same family the
//! `rand_xoshiro` crate provides: fast, 256-bit state, excellent
//! statistical quality for simulation workloads (not cryptographic).

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
    /// Raw `next_u64` invocations since seeding — the observability
    /// determinism contract ("NullSink/RingSink runs draw exactly as
    /// often as a no-obs run") is asserted against this counter.
    draws: u64,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from a 64-bit seed via SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
            draws: 0,
        }
    }

    /// Number of raw `next_u64` draws since seeding.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    pub fn next_u64(&mut self) -> u64 {
        self.draws += 1;
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (Lemire-ish via rejection-free
    /// widening multiply; bias negligible for simulation ranges).
    pub fn range_u(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u128;
        lo + ((self.next_u64() as u128 * span) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` for i64 (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u128 + 1;
        lo + ((self.next_u64() as u128 * span) >> 64) as i64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_u(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(Rng::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn draw_counter_counts_raw_draws() {
        let mut r = Rng::seed_from_u64(11);
        assert_eq!(r.draws(), 0);
        r.next_u64();
        r.f64();
        assert_eq!(r.draws(), 2);
        // gauss draws two uniforms, then serves the spare for free.
        r.gauss();
        assert_eq!(r.draws(), 4);
        r.gauss();
        assert_eq!(r.draws(), 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let x = r.range_u(10, 15);
            assert!((10..15).contains(&x));
            seen[x - 10] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
        for _ in 0..1000 {
            let x = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bool_probability() {
        let mut r = Rng::seed_from_u64(5);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // overwhelmingly
    }
}
