//! Workload simulation substrate: the entity's random walk, per-camera
//! ground-truth visibility, synthetic identity images (CUHK03
//! substitute), the MAN/WAN network model, time-varying per-node
//! compute capacity, schedule-driven fault injection and skewed device
//! clocks.

mod clock;
mod compute;
mod faults;
mod feeds;
mod images;
mod netmodel;
mod walk;

pub use clock::ClockSkews;
pub use compute::ComputeModel;
pub use faults::{backoff_delay, FaultModel};
pub use feeds::{visibility_of, FrameTruth, GroundTruth};
pub use images::{
    identity_embedding, identity_image, identity_image_into,
    IdentityGallery, FEAT_DIM, IMG_DIM, IMG_PATCHES, PATCH_SIZE,
};
pub use netmodel::NetModel;
pub use walk::{EntityWalk, Position};
