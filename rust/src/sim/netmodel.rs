//! MAN/WAN network model between cluster nodes.
//!
//! Each node has a single egress NIC modelled as a FIFO serializer with a
//! (time-varying) bandwidth plus a propagation latency — enough to
//! reproduce the paper's Fig 9 experiment where the inter-node bandwidth
//! drops from 1 Gbps to 30 Mbps mid-run and the congestion cascades into
//! event latencies.

use crate::config::NetworkConfig;
use crate::util::{millis, secs, Micros};

/// Per-node egress serialization queue with scheduled bandwidth changes.
#[derive(Debug, Clone)]
pub struct NetModel {
    latency: Micros,
    /// `(effective_from, bandwidth_bps)` steps, sorted by time.
    bw_schedule: Vec<(Micros, f64)>,
    /// Next time each node's NIC is free.
    nic_free: Vec<Micros>,
    /// Shared-backbone serializer: all inter-node transfers contend on
    /// one fabric (Fig 9 throttles "the bandwidth between compute
    /// nodes" — a switch-level constraint).
    shared: Option<Micros>,
    pub frame_bytes: usize,
    pub candidate_bytes: usize,
    pub meta_bytes: usize,
}

impl NetModel {
    pub fn new(cfg: &NetworkConfig, nodes: usize) -> Self {
        let mut bw_schedule = vec![(0, cfg.bandwidth_bps)];
        for ev in &cfg.events {
            bw_schedule.push((secs(ev.at_sec), ev.bandwidth_bps));
        }
        bw_schedule.sort_by_key(|&(t, _)| t);
        Self {
            latency: millis(cfg.latency_ms),
            bw_schedule,
            nic_free: vec![0; nodes],
            shared: if cfg.shared_fabric { Some(0) } else { None },
            frame_bytes: cfg.frame_bytes,
            candidate_bytes: cfg.candidate_bytes,
            meta_bytes: cfg.meta_bytes,
        }
    }

    /// Bandwidth in effect at time `t`.
    pub fn bandwidth_at(&self, t: Micros) -> f64 {
        self.bw_schedule
            .iter()
            .rev()
            .find(|&&(from, _)| from <= t)
            .map(|&(_, bw)| bw)
            .unwrap_or(self.bw_schedule[0].1)
    }

    /// Serialize `bytes` onto a link starting at `start`, honouring
    /// every scheduled bandwidth step the transfer straddles: the
    /// portion before each boundary serializes at that segment's rate,
    /// the remainder at the next. (Sampling the rate once at `start`
    /// would let a transfer beginning just before a throttle finish
    /// entirely at the stale fast rate.) Returns the serialization
    /// finish time.
    fn serialized_until(&self, start: Micros, bytes: usize) -> Micros {
        let mut remaining_bits = bytes as f64 * 8.0;
        let mut t = start;
        loop {
            let bw = self.bandwidth_at(t);
            let need =
                (remaining_bits / bw * 1e6).ceil().max(0.0) as Micros;
            // Smallest scheduled step strictly after `t` (the schedule
            // is sorted by time).
            let next = self
                .bw_schedule
                .iter()
                .map(|&(from, _)| from)
                .find(|&from| from > t);
            match next {
                Some(boundary) if t + need > boundary => {
                    let sent = (boundary - t) as f64 * bw / 1e6;
                    remaining_bits = (remaining_bits - sent).max(0.0);
                    t = boundary;
                }
                _ => return t + need,
            }
        }
    }

    /// Enqueue a transfer of `bytes` from `src` to `dst` starting at `t`;
    /// returns the arrival time at `dst`. Same-node transfers (IPC via
    /// the Worker's router) cost only a fixed small overhead.
    pub fn transfer(
        &mut self,
        src: usize,
        dst: usize,
        bytes: usize,
        t: Micros,
    ) -> Micros {
        if src == dst {
            return t + 50; // Sys V IPC hop, ~50 us
        }
        if let Some(fabric_free) = self.shared {
            let start = fabric_free.max(t);
            let done = self.serialized_until(start, bytes);
            self.shared = Some(done);
            return done + self.latency;
        }
        let start = self.nic_free[src].max(t);
        let done = self.serialized_until(start, bytes);
        self.nic_free[src] = done;
        done + self.latency
    }

    /// Non-mutating estimate of a transfer duration (no queueing; the
    /// schedule-boundary split still applies).
    pub fn transfer_estimate(&self, bytes: usize, t: Micros) -> Micros {
        self.serialized_until(t, bytes) - t + self.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BandwidthEvent;
    use crate::util::SEC;

    fn cfg() -> NetworkConfig {
        NetworkConfig {
            shared_fabric: false, // exercise the per-NIC mode here
            events: vec![BandwidthEvent {
                at_sec: 300.0,
                bandwidth_bps: 30e6,
            }],
            ..NetworkConfig::default()
        }
    }

    fn cfg_shared() -> NetworkConfig {
        NetworkConfig {
            events: vec![BandwidthEvent {
                at_sec: 300.0,
                bandwidth_bps: 30e6,
            }],
            ..NetworkConfig::default()
        }
    }

    #[test]
    fn shared_fabric_serializes_across_nodes() {
        let mut n = NetModel::new(&cfg_shared(), 4);
        let a = n.transfer(0, 1, 1_000_000, 0);
        let b = n.transfer(2, 3, 1_000_000, 0); // different NICs, same fabric
        assert!(b > a, "fabric is shared");
    }

    #[test]
    fn bandwidth_schedule_applies() {
        let n = NetModel::new(&cfg(), 3);
        assert_eq!(n.bandwidth_at(0), 1e9);
        assert_eq!(n.bandwidth_at(299 * SEC), 1e9);
        assert_eq!(n.bandwidth_at(301 * SEC), 30e6);
    }

    #[test]
    fn transfer_time_scales_with_bandwidth() {
        let mut n = NetModel::new(&cfg(), 3);
        let fast = n.transfer(0, 1, 2900, 0) - 0;
        let slow = n.transfer(0, 1, 2900, 400 * SEC) - 400 * SEC;
        // 2900 B at 1 Gbps ~ 23 us + 500 us latency; at 30 Mbps ~ 773 us.
        assert!(fast < slow);
        assert!((slow - fast) > 600);
    }

    #[test]
    fn nic_serializes_concurrent_transfers() {
        let mut n = NetModel::new(&cfg(), 2);
        let a = n.transfer(0, 1, 1_000_000, 0);
        let b = n.transfer(0, 1, 1_000_000, 0);
        assert!(b > a, "second transfer must queue behind the first");
        let c = n.transfer(1, 0, 1_000_000, 0);
        assert_eq!(c, a, "different NIC is independent");
    }

    #[test]
    fn same_node_is_ipc() {
        let mut n = NetModel::new(&cfg(), 2);
        assert_eq!(n.transfer(1, 1, 5_000_000, 100), 150);
    }

    #[test]
    fn transfer_straddling_throttle_splits_at_boundary() {
        // Regression: serialization used to sample the bandwidth once
        // at `start`, so a transfer beginning just before the 300 s
        // throttle serialized *entirely* at the stale 1 Gbps. 25 MB
        // (200 Mbit) starting 0.1 s before the step: 100 Mbit fit at
        // 1 Gbps, the remaining 100 Mbit take ~3.33 s at 30 Mbps.
        let mut n = NetModel::new(&cfg(), 2);
        let start = 300 * SEC - SEC / 10;
        let end = n.transfer(0, 1, 25_000_000, start);
        assert!(
            end > 303 * SEC,
            "remainder serialized at the stale fast rate: end={end}"
        );
        assert!(
            end < 304 * SEC,
            "pre-boundary portion over-throttled: end={end}"
        );
        // The NIC is busy until serialization completes.
        let follow = n.transfer(0, 1, 1, 300 * SEC);
        assert!(follow >= end - 1000, "follow={follow} end={end}");

        // A transfer entirely inside one segment is unchanged relative
        // to the single-sample model.
        let mut m = NetModel::new(&cfg(), 2);
        let e2 = m.transfer(0, 1, 2_900, 0);
        let ser = (2_900f64 * 8.0 / 1e9 * 1e6).ceil() as Micros;
        assert_eq!(e2, ser + millis(0.5));

        // The shared-fabric path splits at the boundary too.
        let mut s = NetModel::new(&cfg_shared(), 2);
        let end = s.transfer(0, 1, 25_000_000, start);
        assert!(end > 303 * SEC, "shared fabric: end={end}");

        // The non-mutating estimate honours the split as well.
        let est = m.transfer_estimate(25_000_000, start);
        assert!(est > 3 * SEC, "estimate ignored the boundary: {est}");
    }

    #[test]
    fn congestion_collapse_at_low_bandwidth() {
        // 200 cameras x 2.9 kB/s = 4.6 Mbps fits in 30 Mbps, but
        // 2000 frames/s would not — verify queueing grows unbounded.
        let mut n = NetModel::new(&cfg(), 2);
        let t0 = 400 * SEC;
        let mut last = 0;
        for _ in 0..2000 {
            last = n.transfer(0, 1, 2900, t0);
        }
        // 2000 * 2900B * 8 / 30e6 = 1.55 s of serialization
        assert!(last - t0 > SEC, "got {}", last - t0);
    }
}
