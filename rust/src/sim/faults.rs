//! Deterministic fault injection: the failure-domain limit of the
//! dynamism machinery.
//!
//! PR 5's [`super::ComputeModel`]/[`super::NetModel`] make resources
//! *slower* on a schedule; [`FaultModel`] makes them *fail* on one —
//! the factor → ∞ limiting case the ROADMAP's sharding north-star
//! prices node-dark scenarios with. Faults are schedule-driven data
//! ([`crate::config::FaultEvent`]), never sampled at injection time, so
//! the determinism contract extends cleanly: the same `fault_events`
//! under the same seed replay bit-identically, and an **empty** schedule
//! short-circuits every query ([`FaultModel::is_static`]) so
//! failure-free runs stay bit-identical to a build without the fault
//! machinery at all (`prop_faults` asserts this).
//!
//! The model answers point-in-time and interval queries; the engines
//! own the *consequences* (timeout + bounded-backoff retry, orphan
//! re-dispatch, TL degradation, `lost_to_fault` accounting). A node
//! crash is deliberately **not** a literal infinite execution duration
//! — that would wedge the event heap — but an aliveness predicate the
//! engines consult at batch formation and completion.

use crate::config::{FaultEvent, FaultKind, RecoveryConfig};
use crate::util::{millis, secs, Micros};

/// Per-resource `(effective_from, down?)` step schedules compiled from
/// a [`FaultEvent`] list. Overlapping windows on the same resource
/// resolve last-step-wins (like the compute schedule); schedules are
/// intended to be non-overlapping per resource.
#[derive(Debug, Clone, Default)]
pub struct FaultModel {
    /// Per-node `(from, down)` steps, sorted by time.
    node_steps: Vec<Vec<(Micros, bool)>>,
    /// Per-camera `(from, down)` steps, sorted by time.
    cam_steps: Vec<Vec<(Micros, bool)>>,
    /// Partitioned links as `(min_node, max_node, from, until)`
    /// half-open windows (`until = Micros::MAX` when permanent).
    links: Vec<(usize, usize, Micros, Micros)>,
    /// Message-loss windows `(from, until, prob)`.
    loss: Vec<(Micros, Micros, f64)>,
    /// Sorted, deduped times at which any node/camera flips state —
    /// the engines schedule a fault tick at each so crash consequences
    /// (orphan drains, restarts, TL refresh) happen at the right
    /// virtual instant.
    transitions: Vec<Micros>,
    /// No events at all: every query short-circuits to "healthy" so
    /// failure-free runs pay nothing and stay bit-identical.
    is_static: bool,
}

impl FaultModel {
    /// Compile the schedule for `nodes` cluster nodes and `cameras`
    /// cameras. Out-of-range node/camera indices are ignored (like
    /// [`super::ComputeModel`]).
    pub fn new(
        events: &[FaultEvent],
        nodes: usize,
        cameras: usize,
    ) -> Self {
        if events.is_empty() {
            return Self {
                is_static: true,
                ..Self::default()
            };
        }
        let mut m = Self {
            node_steps: vec![Vec::new(); nodes],
            cam_steps: vec![Vec::new(); cameras],
            is_static: false,
            ..Self::default()
        };
        for ev in events {
            let at = secs(ev.at_sec);
            match ev.kind {
                FaultKind::NodeCrash { node, down_secs } => {
                    if let Some(s) = m.node_steps.get_mut(node) {
                        s.push((at, true));
                        m.transitions.push(at);
                        if let Some(d) = down_secs {
                            let up = at + secs(d);
                            s.push((up, false));
                            m.transitions.push(up);
                        }
                    }
                }
                FaultKind::CameraOutage { camera, down_secs } => {
                    if let Some(s) = m.cam_steps.get_mut(camera) {
                        s.push((at, true));
                        m.transitions.push(at);
                        if let Some(d) = down_secs {
                            let up = at + secs(d);
                            s.push((up, false));
                            m.transitions.push(up);
                        }
                    }
                }
                FaultKind::LinkPartition { a, b, down_secs } => {
                    let until = down_secs
                        .map(|d| at + secs(d))
                        .unwrap_or(Micros::MAX);
                    m.links.push((a.min(b), a.max(b), at, until));
                }
                FaultKind::MessageLoss { prob, dur_secs } => {
                    let until = dur_secs
                        .map(|d| at + secs(d))
                        .unwrap_or(Micros::MAX);
                    m.loss.push((at, until, prob.clamp(0.0, 1.0)));
                }
            }
        }
        for s in m.node_steps.iter_mut().chain(m.cam_steps.iter_mut())
        {
            s.sort_by_key(|&(t, _)| t);
        }
        m.transitions.sort_unstable();
        m.transitions.dedup();
        // Post-compile invariants: every step/window list the queries
        // binary-search or scan is sorted, transitions are strictly
        // increasing after the dedup, and loss probabilities survived
        // the clamp — a malformed model here would fail far away, as a
        // non-deterministic aliveness answer mid-run.
        crate::strict_assert!(
            m.node_steps
                .iter()
                .chain(m.cam_steps.iter())
                .all(|s| s.windows(2).all(|w| w[0].0 <= w[1].0)),
            "fault model step schedule not sorted by time"
        );
        crate::strict_assert!(
            m.transitions.windows(2).all(|w| w[0] < w[1]),
            "fault model transitions not strictly increasing"
        );
        crate::strict_assert!(
            m.loss
                .iter()
                .all(|&(from, until, p)| from <= until && (0.0..=1.0).contains(&p)),
            "fault model loss window malformed"
        );
        m
    }

    /// True when no faults are scheduled (every query is "healthy").
    pub fn is_static(&self) -> bool {
        self.is_static
    }

    /// Times at which any node or camera flips state — the engines'
    /// fault-tick schedule.
    pub fn transitions(&self) -> &[Micros] {
        &self.transitions
    }

    fn steps_alive(steps: &[Vec<(Micros, bool)>], i: usize, t: Micros) -> bool {
        match steps.get(i) {
            None => true,
            Some(s) => !s
                .iter()
                .rev()
                .find(|&&(from, _)| from <= t)
                .map(|&(_, down)| down)
                .unwrap_or(false),
        }
    }

    /// Is `node` up at time `t`?
    pub fn node_alive(&self, node: usize, t: Micros) -> bool {
        if self.is_static {
            return true;
        }
        Self::steps_alive(&self.node_steps, node, t)
    }

    /// Is camera `cam` producing frames at time `t`?
    pub fn camera_alive(&self, cam: usize, t: Micros) -> bool {
        if self.is_static {
            return true;
        }
        Self::steps_alive(&self.cam_steps, cam, t)
    }

    /// Was `node` down at any instant in the half-open window
    /// `(from, to]`? This is the in-flight-batch question: a batch
    /// dispatched at `from` whose completion pops at `to` is void if
    /// its node died anywhere in between — even if it also restarted.
    pub fn node_down_during(
        &self,
        node: usize,
        from: Micros,
        to: Micros,
    ) -> bool {
        if self.is_static {
            return false;
        }
        if !self.node_alive(node, to) {
            return true;
        }
        self.node_steps
            .get(node)
            .map(|s| {
                s.iter().any(|&(t, down)| down && from < t && t <= to)
            })
            .unwrap_or(false)
    }

    /// The node's next restart time strictly after `t`, if any.
    pub fn node_revives_at(
        &self,
        node: usize,
        t: Micros,
    ) -> Option<Micros> {
        if self.is_static {
            return None;
        }
        self.node_steps.get(node).and_then(|s| {
            s.iter()
                .find(|&&(from, down)| !down && from > t)
                .map(|&(from, _)| from)
        })
    }

    /// Is the (bidirectional) link between `a` and `b` up at `t`?
    /// Intra-node traffic (`a == b`) never partitions.
    pub fn link_up(&self, a: usize, b: usize, t: Micros) -> bool {
        if self.is_static || a == b {
            return true;
        }
        let key = (a.min(b), a.max(b));
        !self.links.iter().any(|&(la, lb, from, until)| {
            (la, lb) == key && from <= t && t < until
        })
    }

    /// Message-loss probability in effect at `t` (max over open
    /// windows; 0.0 when none — callers must skip their RNG draw then,
    /// so loss-free schedules leave the fault RNG stream untouched).
    pub fn loss_prob(&self, t: Micros) -> f64 {
        if self.is_static {
            return 0.0;
        }
        self.loss
            .iter()
            .filter(|&&(from, until, _)| from <= t && t < until)
            .map(|&(_, _, p)| p)
            .fold(0.0, f64::max)
    }

    /// True when any message-loss window is configured (used to decide
    /// whether delivery must consult the fault RNG at all).
    pub fn has_loss(&self) -> bool {
        !self.loss.is_empty()
    }
}

/// Exponential-backoff delay for retry attempt `k` (0-based) under
/// `rc`: `backoff_base_ms * 2^k`, as Micros.
pub fn backoff_delay(rc: &RecoveryConfig, attempt: u32) -> Micros {
    millis(rc.backoff_base_ms * f64::powi(2.0, attempt.min(16) as i32))
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    fn ev(at_sec: f64, kind: FaultKind) -> FaultEvent {
        FaultEvent { at_sec, kind }
    }

    #[test]
    fn empty_schedule_is_static_and_healthy() {
        let m = FaultModel::new(&[], 4, 10);
        assert!(m.is_static());
        assert!(m.node_alive(0, 500 * SEC));
        assert!(m.camera_alive(9, 500 * SEC));
        assert!(m.link_up(0, 3, 500 * SEC));
        assert_eq!(m.loss_prob(500 * SEC), 0.0);
        assert!(!m.has_loss());
        assert!(m.transitions().is_empty());
        assert!(!m.node_down_during(0, 0, 1000 * SEC));
    }

    #[test]
    fn crash_restart_window() {
        let m = FaultModel::new(
            &[ev(
                100.0,
                FaultKind::NodeCrash { node: 1, down_secs: Some(50.0) },
            )],
            3,
            0,
        );
        assert!(m.node_alive(1, 99 * SEC));
        assert!(!m.node_alive(1, 100 * SEC));
        assert!(!m.node_alive(1, 149 * SEC));
        assert!(m.node_alive(1, 150 * SEC));
        assert!(m.node_alive(0, 120 * SEC), "other nodes unaffected");
        assert_eq!(m.node_revives_at(1, 100 * SEC), Some(150 * SEC));
        assert_eq!(m.transitions(), &[100 * SEC, 150 * SEC]);
        // The in-flight window question: down during (90, 110]; clean
        // before and after the outage.
        assert!(m.node_down_during(1, 90 * SEC, 110 * SEC));
        assert!(!m.node_down_during(1, 10 * SEC, 90 * SEC));
        assert!(!m.node_down_during(1, 151 * SEC, 200 * SEC));
        // A window spanning the whole outage still saw the crash.
        assert!(m.node_down_during(1, 90 * SEC, 200 * SEC));
    }

    #[test]
    fn permanent_crash_never_revives() {
        let m = FaultModel::new(
            &[ev(
                10.0,
                FaultKind::NodeCrash { node: 0, down_secs: None },
            )],
            1,
            0,
        );
        assert!(!m.node_alive(0, 9999 * SEC));
        assert_eq!(m.node_revives_at(0, 10 * SEC), None);
    }

    #[test]
    fn camera_flap() {
        let m = FaultModel::new(
            &[
                ev(
                    5.0,
                    FaultKind::CameraOutage {
                        camera: 2,
                        down_secs: Some(3.0),
                    },
                ),
                ev(
                    20.0,
                    FaultKind::CameraOutage {
                        camera: 2,
                        down_secs: Some(2.0),
                    },
                ),
            ],
            0,
            4,
        );
        assert!(m.camera_alive(2, 4 * SEC));
        assert!(!m.camera_alive(2, 6 * SEC));
        assert!(m.camera_alive(2, 10 * SEC));
        assert!(!m.camera_alive(2, 21 * SEC));
        assert!(m.camera_alive(2, 22 * SEC));
        assert_eq!(m.transitions().len(), 4);
    }

    #[test]
    fn link_partition_is_symmetric_and_heals() {
        let m = FaultModel::new(
            &[ev(
                50.0,
                FaultKind::LinkPartition {
                    a: 3,
                    b: 1,
                    down_secs: Some(25.0),
                },
            )],
            4,
            0,
        );
        assert!(m.link_up(1, 3, 49 * SEC));
        assert!(!m.link_up(1, 3, 50 * SEC));
        assert!(!m.link_up(3, 1, 60 * SEC), "symmetric");
        assert!(m.link_up(3, 1, 75 * SEC));
        assert!(m.link_up(0, 2, 60 * SEC), "other links unaffected");
        assert!(m.link_up(1, 1, 60 * SEC), "loopback never partitions");
    }

    #[test]
    fn loss_windows_and_clamping() {
        let m = FaultModel::new(
            &[
                ev(
                    10.0,
                    FaultKind::MessageLoss {
                        prob: 0.25,
                        dur_secs: Some(10.0),
                    },
                ),
                ev(
                    15.0,
                    FaultKind::MessageLoss {
                        prob: 2.0,
                        dur_secs: Some(1.0),
                    },
                ),
            ],
            1,
            1,
        );
        assert!(m.has_loss());
        assert_eq!(m.loss_prob(9 * SEC), 0.0);
        assert_eq!(m.loss_prob(12 * SEC), 0.25);
        assert_eq!(m.loss_prob(15 * SEC), 1.0, "clamped to 1");
        assert_eq!(m.loss_prob(25 * SEC), 0.0);
    }

    #[test]
    fn out_of_range_indices_are_ignored() {
        let m = FaultModel::new(
            &[
                ev(
                    1.0,
                    FaultKind::NodeCrash { node: 99, down_secs: None },
                ),
                ev(
                    1.0,
                    FaultKind::CameraOutage {
                        camera: 99,
                        down_secs: None,
                    },
                ),
            ],
            2,
            2,
        );
        assert!(m.node_alive(0, 10 * SEC));
        assert!(m.camera_alive(0, 10 * SEC));
        assert!(m.transitions().is_empty());
    }

    #[test]
    fn backoff_doubles_per_attempt() {
        let rc = RecoveryConfig {
            enabled: true,
            max_retries: 3,
            backoff_base_ms: 250.0,
        };
        assert_eq!(backoff_delay(&rc, 0), millis(250.0));
        assert_eq!(backoff_delay(&rc, 1), millis(500.0));
        assert_eq!(backoff_delay(&rc, 2), millis(1000.0));
    }
}
