//! Entity random walk over the road network (§5.1 Workload).
//!
//! The tracked entity starts at a vertex and performs a random walk at a
//! constant speed (paper: 1 m/s), interpolating along edges. The walk is
//! pre-computed for the experiment duration so `position(t)` is O(log n).

use crate::roadnet::{Graph, VertexId};
use crate::util::{rng, Micros, SEC};

/// A point along the walk: on the edge `(from, to)` having covered
/// `offset_m` of its `len_m`.
#[derive(Debug, Clone, Copy)]
pub struct Position {
    pub from: VertexId,
    pub to: VertexId,
    pub offset_m: f64,
    pub len_m: f64,
    /// Planar coordinates (metres).
    pub xy: (f64, f64),
}

/// Pre-computed random walk.
#[derive(Debug, Clone)]
pub struct EntityWalk {
    /// (arrival_time, vertex) for each vertex visited, in order.
    visits: Vec<(Micros, VertexId)>,
    speed_mps: f64,
}

impl EntityWalk {
    /// Simulate a walk of `duration` starting at `start`. Avoids
    /// immediately backtracking unless the vertex is a dead end.
    pub fn simulate(
        g: &Graph,
        start: VertexId,
        speed_mps: f64,
        duration: Micros,
        seed: u64,
    ) -> Self {
        let mut r = rng(seed, 0x11A1);
        let mut visits = vec![(0, start)];
        let mut t = 0;
        let mut cur = start;
        let mut prev: Option<VertexId> = None;
        while t < duration {
            let nbrs = g.neighbors(cur);
            if nbrs.is_empty() {
                break; // isolated vertex: entity stays put
            }
            let choices: Vec<&(VertexId, f64)> = nbrs
                .iter()
                .filter(|&&(v, _)| Some(v) != prev || nbrs.len() == 1)
                .collect();
            let &(next, len) = choices[r.range_u(0, choices.len())];
            let dt = (len / speed_mps * SEC as f64).round() as Micros;
            t += dt.max(1);
            prev = Some(cur);
            cur = next;
            visits.push((t, cur));
        }
        Self {
            visits,
            speed_mps,
        }
    }

    pub fn speed(&self) -> f64 {
        self.speed_mps
    }

    /// Position at time `t` (clamped to the walk's extent).
    pub fn position(&self, g: &Graph, t: Micros) -> Position {
        let idx = match self.visits.binary_search_by_key(&t, |&(vt, _)| vt) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        let (t0, v0) = self.visits[idx];
        if idx + 1 >= self.visits.len() {
            let xy = g.pos[v0];
            return Position {
                from: v0,
                to: v0,
                offset_m: 0.0,
                len_m: 0.0,
                xy,
            };
        }
        let (t1, v1) = self.visits[idx + 1];
        let len = g.edge_len(v0, v1).unwrap_or(0.0);
        let frac = if t1 > t0 {
            ((t - t0) as f64 / (t1 - t0) as f64).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let (x0, y0) = g.pos[v0];
        let (x1, y1) = g.pos[v1];
        Position {
            from: v0,
            to: v1,
            offset_m: frac * len,
            len_m: len,
            xy: (x0 + frac * (x1 - x0), y0 + frac * (y1 - y0)),
        }
    }

    /// The vertex visited most recently at or before `t`.
    pub fn vertex_at(&self, t: Micros) -> VertexId {
        let idx = match self.visits.binary_search_by_key(&t, |&(vt, _)| vt) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        self.visits[idx].1
    }

    pub fn visits(&self) -> &[(Micros, VertexId)] {
        &self.visits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::generate;
    use crate::util::secs;

    fn setup() -> (Graph, EntityWalk) {
        let g = generate(&WorkloadConfig::default(), 5);
        let w = EntityWalk::simulate(&g, 0, 1.0, secs(600.0), 5);
        (g, w)
    }

    #[test]
    fn walk_respects_speed() {
        let (g, w) = setup();
        // Total distance covered / total time ~ speed.
        let visits = w.visits();
        let mut dist = 0.0;
        for pair in visits.windows(2) {
            dist += g.edge_len(pair[0].1, pair[1].1).unwrap();
        }
        let dt = (visits.last().unwrap().0 - visits[0].0) as f64 / 1e6;
        let v = dist / dt;
        assert!((v - 1.0).abs() < 0.01, "speed {v}");
    }

    #[test]
    fn walk_covers_duration() {
        let (_, w) = setup();
        assert!(w.visits().last().unwrap().0 >= secs(600.0));
    }

    #[test]
    fn positions_interpolate_continuously() {
        let (g, w) = setup();
        let mut last = w.position(&g, 0).xy;
        for s in 1..600 {
            let p = w.position(&g, secs(s as f64)).xy;
            let step =
                ((p.0 - last.0).powi(2) + (p.1 - last.1).powi(2)).sqrt();
            // 1 m/s => at most ~1.05 m per second step (edge wiggle).
            assert!(step < 1.6, "jump of {step} m at t={s}s");
            last = p;
        }
    }

    #[test]
    fn walk_moves_along_edges() {
        let (g, w) = setup();
        let p = w.position(&g, secs(42.5));
        assert!(g.has_edge(p.from, p.to) || p.from == p.to);
        assert!(p.offset_m <= p.len_m + 1e-9);
    }

    #[test]
    fn deterministic() {
        let g = generate(&WorkloadConfig::default(), 5);
        let a = EntityWalk::simulate(&g, 0, 1.0, secs(60.0), 9);
        let b = EntityWalk::simulate(&g, 0, 1.0, secs(60.0), 9);
        assert_eq!(a.visits(), b.visits());
    }
}
