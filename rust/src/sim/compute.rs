//! Time-varying compute capacity of the cluster nodes.
//!
//! The paper's §6 dynamism claim is two-sided: the platform must ride
//! out variability in the *network* ([`super::NetModel`]'s bandwidth
//! schedule, Fig 9) **and** in the *compute* resources — a fog node
//! that gets co-tenanted, thermally throttled or migrated mid-run.
//! [`ComputeModel`] mirrors the bandwidth schedule for execution
//! speed: per-node `(time, slowdown factor)` steps
//! ([`crate::config::ComputeEvent`]) that scale the *actual* duration
//! of every batch executed on that node from the step onward. The ξ
//! estimators never see this model directly — they only see its effect
//! through observed durations, which is exactly what the online-ξ
//! calibration loop (`ServiceConfig::online_xi`) re-estimates and the
//! frozen-ξ baseline mispredicts.

use crate::config::ComputeEvent;
use crate::util::{secs, Micros};

/// Per-node execution-slowdown schedule.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Per-node `(effective_from, factor)` steps, sorted by time.
    schedules: Vec<Vec<(Micros, f64)>>,
    /// No events at all: `factor_at` short-circuits to 1.0 so static
    /// runs pay nothing (and stay bit-identical by construction).
    is_static: bool,
}

impl ComputeModel {
    /// Build the model for `nodes` cluster nodes. An event with
    /// `node: None` applies to every node (the "all fog nodes slow
    /// down" scenario); an out-of-range node index is ignored.
    pub fn new(events: &[ComputeEvent], nodes: usize) -> Self {
        let mut schedules = vec![vec![(0, 1.0)]; nodes];
        for ev in events {
            match ev.node {
                Some(n) => {
                    if let Some(s) = schedules.get_mut(n) {
                        s.push((secs(ev.at_sec), ev.factor));
                    }
                }
                None => {
                    for s in schedules.iter_mut() {
                        s.push((secs(ev.at_sec), ev.factor));
                    }
                }
            }
        }
        for s in schedules.iter_mut() {
            s.sort_by_key(|&(t, _)| t);
        }
        Self {
            schedules,
            is_static: events.is_empty(),
        }
    }

    /// Slowdown factor in effect on `node` at time `t` (1.0 = nominal
    /// speed, 4.0 = four times slower).
    pub fn factor_at(&self, node: usize, t: Micros) -> f64 {
        if self.is_static {
            return 1.0;
        }
        self.schedules
            .get(node)
            .and_then(|s| {
                s.iter().rev().find(|&&(from, _)| from <= t)
            })
            .map(|&(_, f)| f)
            .unwrap_or(1.0)
    }

    /// True when no compute events are scheduled (every factor is 1.0).
    pub fn is_static(&self) -> bool {
        self.is_static
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    fn ev(at_sec: f64, node: Option<usize>, factor: f64) -> ComputeEvent {
        ComputeEvent {
            at_sec,
            node,
            factor,
        }
    }

    #[test]
    fn static_model_is_unit() {
        let m = ComputeModel::new(&[], 4);
        assert!(m.is_static());
        for node in 0..4 {
            assert_eq!(m.factor_at(node, 0), 1.0);
            assert_eq!(m.factor_at(node, 1000 * SEC), 1.0);
        }
    }

    #[test]
    fn scheduled_slowdown_applies_from_its_step() {
        let m = ComputeModel::new(&[ev(300.0, None, 4.0)], 3);
        assert_eq!(m.factor_at(1, 299 * SEC), 1.0);
        assert_eq!(m.factor_at(1, 300 * SEC), 4.0);
        assert_eq!(m.factor_at(2, 500 * SEC), 4.0);
    }

    #[test]
    fn per_node_events_are_scoped() {
        let m = ComputeModel::new(&[ev(100.0, Some(1), 2.0)], 3);
        assert_eq!(m.factor_at(0, 200 * SEC), 1.0);
        assert_eq!(m.factor_at(1, 200 * SEC), 2.0);
        assert_eq!(m.factor_at(2, 200 * SEC), 1.0);
        // Out-of-range node indices are ignored, not a panic.
        let m = ComputeModel::new(&[ev(100.0, Some(99), 2.0)], 3);
        assert_eq!(m.factor_at(0, 200 * SEC), 1.0);
    }

    #[test]
    fn recovery_steps_restore_speed() {
        let m = ComputeModel::new(
            &[ev(100.0, None, 4.0), ev(200.0, None, 1.0)],
            2,
        );
        assert_eq!(m.factor_at(0, 150 * SEC), 4.0);
        assert_eq!(m.factor_at(0, 250 * SEC), 1.0);
    }

    #[test]
    fn unknown_node_queries_are_unit() {
        let m = ComputeModel::new(&[ev(0.0, None, 3.0)], 1);
        assert_eq!(m.factor_at(7, SEC), 1.0);
    }
}
