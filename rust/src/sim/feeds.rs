//! Ground-truth visibility: which camera sees the entity when.
//!
//! The feed simulator publishes 1-fps timestamped frames per camera
//! (true negatives, switching to true positives while the entity is in
//! that camera's FOV) — this module pre-computes the visibility truth the
//! frames are labelled with, replacing the paper's Kafka image feeds.

use crate::roadnet::{Camera, Graph};
use crate::sim::walk::EntityWalk;
use crate::util::{Micros, SEC};

/// Ground-truth label attached to one frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameTruth {
    /// Entity inside this camera's FOV at capture time.
    pub entity_present: bool,
}

/// Per-camera visibility intervals for an entity walk.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    /// For each camera, sorted disjoint `(start, end)` intervals during
    /// which the entity is within FOV.
    pub intervals: Vec<Vec<(Micros, Micros)>>,
    /// Sampling step used to build the intervals.
    pub step: Micros,
}

impl GroundTruth {
    /// Sample the walk at `step` resolution (default 200 ms at 1 fps
    /// feeds is ample: FOV transit at 1 m/s through a 40 m radius takes
    /// tens of seconds).
    pub fn compute(
        g: &Graph,
        cams: &[Camera],
        walk: &EntityWalk,
        duration: Micros,
        step: Micros,
    ) -> Self {
        let mut intervals = vec![Vec::new(); cams.len()];
        let mut open: Vec<Option<Micros>> = vec![None; cams.len()];
        let mut t = 0;
        while t <= duration {
            let p = walk.position(g, t).xy;
            for c in cams {
                let sees = c.sees(g, p);
                match (sees, open[c.id]) {
                    (true, None) => open[c.id] = Some(t),
                    (false, Some(s)) => {
                        intervals[c.id].push((s, t));
                        open[c.id] = None;
                    }
                    _ => {}
                }
            }
            t += step;
        }
        for (id, o) in open.iter().enumerate() {
            if let Some(s) = o {
                intervals[id].push((*s, duration));
            }
        }
        Self { intervals, step }
    }

    /// Is the entity visible to `cam` at `t`?
    pub fn visible(&self, cam: usize, t: Micros) -> bool {
        self.interval_index(cam, t).is_some()
    }

    /// Index of the visibility interval (transit) containing `t`.
    pub fn interval_index(&self, cam: usize, t: Micros) -> Option<usize> {
        self.intervals[cam]
            .iter()
            .position(|&(s, e)| t >= s && t < e)
    }

    /// Total seconds the entity is visible to any camera.
    pub fn covered_secs(&self) -> f64 {
        // Merge across cameras on the sampling grid.
        let mut pts: Vec<(Micros, i32)> = Vec::new();
        for iv in &self.intervals {
            for &(s, e) in iv {
                pts.push((s, 1));
                pts.push((e, -1));
            }
        }
        pts.sort();
        let (mut depth, mut covered, mut last) = (0, 0i64, 0);
        for (t, d) in pts {
            if depth > 0 {
                covered += t - last;
            }
            depth += d;
            last = t;
        }
        covered as f64 / SEC as f64
    }
}

/// Truth label for the frame captured by `cam` at `t`.
pub fn visibility_of(gt: &GroundTruth, cam: usize, t: Micros) -> FrameTruth {
    FrameTruth {
        entity_present: gt.visible(cam, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::{generate, place_cameras};
    use crate::util::secs;

    fn setup() -> (Graph, Vec<Camera>, EntityWalk, GroundTruth) {
        let g = generate(&WorkloadConfig::default(), 5);
        let cams = place_cameras(&g, 1000, 0, 40.0);
        let walk = EntityWalk::simulate(&g, 0, 1.0, secs(600.0), 5);
        let gt = GroundTruth::compute(&g, &cams, &walk, secs(600.0), 200_000);
        (g, cams, walk, gt)
    }

    #[test]
    fn entity_visible_at_start() {
        let (_, _, _, gt) = setup();
        // Walk starts at vertex 0 = camera 0's vertex.
        assert!(gt.visible(0, 0));
    }

    #[test]
    fn visibility_matches_fov_geometry() {
        let (g, cams, walk, gt) = setup();
        for s in (0..600).step_by(7) {
            let t = secs(s as f64);
            let p = walk.position(&g, t).xy;
            for c in cams.iter().take(50) {
                assert_eq!(
                    gt.visible(c.id, t),
                    c.sees(&g, p),
                    "cam {} t {}s",
                    c.id,
                    s
                );
            }
        }
    }

    #[test]
    fn coverage_is_partial() {
        // With full camera deployment, blind spots exist but so do
        // sightings (cameras at every vertex, FOV 40 m, roads ~85 m).
        let (_, _, _, gt) = setup();
        let cov = gt.covered_secs();
        assert!(cov > 60.0, "covered {cov}s");
        assert!(cov < 600.0, "covered {cov}s");
    }

    #[test]
    fn intervals_sorted_disjoint() {
        let (_, _, _, gt) = setup();
        for iv in &gt.intervals {
            for w in iv.windows(2) {
                assert!(w[0].1 <= w[1].0);
            }
            for &(s, e) in iv {
                assert!(s < e);
            }
        }
    }
}
