//! Synthetic identity images — the CUHK03 dataset substitute.
//!
//! Frames are generated exactly like `python/compile/weights.py`'s
//! `make_identity_image`: a unit-norm identity code broadcast across
//! patches plus per-frame Gaussian noise. The AOT-compiled VA/CR models
//! (whose stem is a patch mean-pool) recover the code, so same-identity
//! frames embed close to the query and different identities far away —
//! giving the controllable true-positive/negative labels the paper got
//! from CUHK03.
//!
//! The distributions need not match Python bit-for-bit (each side
//! generates its own gallery); only the *model weights* cross the
//! language boundary, via `artifacts/weights.bin`.

use crate::util::{FastMap, Rng};

/// Patches per frame (must match `weights.IMG_PATCHES`).
pub const IMG_PATCHES: usize = 64;
/// Pixels per patch (must match `weights.PATCH_SIZE`).
pub const PATCH_SIZE: usize = 128;
/// Flattened frame length.
pub const IMG_DIM: usize = IMG_PATCHES * PATCH_SIZE;
/// Re-id embedding dimension (must match `weights.FEAT_DIM`).
pub const FEAT_DIM: usize = 128;

/// Unit-norm identity code, deterministic per identity.
pub fn identity_embedding(identity: u64) -> Vec<f32> {
    let mut r = Rng::seed_from_u64(0xC0FF_EE00 ^ identity);
    let mut e: Vec<f32> =
        (0..IMG_PATCHES).map(|_| r.gauss() as f32).collect();
    let norm = e.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    e.iter_mut().for_each(|x| *x /= norm);
    e
}

/// Write the synthetic frame for `(identity, frame)` into `out`
/// (cleared first), given the identity's embedding.
fn write_image(
    e: &[f32],
    identity: u64,
    frame: u64,
    noise: f32,
    out: &mut Vec<f32>,
) {
    let mut r =
        Rng::seed_from_u64(identity.wrapping_mul(1_000_003) ^ frame);
    out.clear();
    out.reserve(IMG_DIM);
    for code in e.iter().take(IMG_PATCHES) {
        for _ in 0..PATCH_SIZE {
            out.push(code + noise * r.gauss() as f32);
        }
    }
}

/// Synthetic frame into a caller-provided buffer (cleared first):
/// IMG_DIM = 8192 floats per frame, so the per-frame allocation matters
/// on the feed/bench hot paths. Recomputes the embedding; use
/// [`IdentityGallery`] to amortise that too.
pub fn identity_image_into(
    identity: u64,
    frame: u64,
    noise: f32,
    out: &mut Vec<f32>,
) {
    let e = identity_embedding(identity);
    write_image(&e, identity, frame, noise, out);
}

/// Synthetic frame: identity code broadcast across patches + noise.
pub fn identity_image(identity: u64, frame: u64, noise: f32) -> Vec<f32> {
    let mut img = Vec::with_capacity(IMG_DIM);
    identity_image_into(identity, frame, noise, &mut img);
    img
}

/// Memoised identity embeddings + buffer-reusing frame generation.
///
/// The live engine regenerates frames at camera rate; recomputing the
/// identity code (64 Gaussian draws + a normalisation) per frame is
/// pure waste since identities recur — the tracked entity on every
/// positive frame, a bounded pool of background identities otherwise.
/// The gallery computes each embedding once.
#[derive(Default)]
pub struct IdentityGallery {
    cache: FastMap<u64, Vec<f32>>,
}

impl IdentityGallery {
    pub fn new() -> Self {
        Self::default()
    }

    /// The identity's unit-norm code, computed on first use.
    pub fn embedding(&mut self, identity: u64) -> &[f32] {
        self.cache
            .entry(identity)
            .or_insert_with(|| identity_embedding(identity))
            .as_slice()
    }

    /// Generate `(identity, frame)`'s pixels into `out` (cleared
    /// first), reusing the cached embedding.
    pub fn image_into(
        &mut self,
        identity: u64,
        frame: u64,
        noise: f32,
        out: &mut Vec<f32>,
    ) {
        let e = self
            .cache
            .entry(identity)
            .or_insert_with(|| identity_embedding(identity));
        write_image(e.as_slice(), identity, frame, noise, out);
    }

    /// Allocating convenience wrapper over [`Self::image_into`].
    pub fn image(
        &mut self,
        identity: u64,
        frame: u64,
        noise: f32,
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(IMG_DIM);
        self.image_into(identity, frame, noise, &mut out);
        out
    }

    /// Distinct identities cached so far.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_unit_norm_and_deterministic() {
        let a = identity_embedding(5);
        let b = identity_embedding(5);
        assert_eq!(a, b);
        let n: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-5);
    }

    #[test]
    fn different_identities_are_nearly_orthogonal() {
        let a = identity_embedding(1);
        let b = identity_embedding(2);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(dot.abs() < 0.5, "dot = {dot}");
    }

    #[test]
    fn image_patch_means_recover_code() {
        let e = identity_embedding(9);
        let img = identity_image(9, 3, 0.25);
        for p in 0..IMG_PATCHES {
            let mean: f32 = img[p * PATCH_SIZE..(p + 1) * PATCH_SIZE]
                .iter()
                .sum::<f32>()
                / PATCH_SIZE as f32;
            // noise/sqrt(128) ~ 0.022 std
            assert!((mean - e[p]).abs() < 0.12, "patch {p}");
        }
    }

    #[test]
    fn frames_differ_but_identities_persist() {
        let a = identity_image(9, 0, 0.25);
        let b = identity_image(9, 1, 0.25);
        assert_ne!(a, b);
        // Correlation across frames of the same identity: the signal
        // power is 128 (unit code over 64 patches x 128 px) vs noise
        // power 8192 * 0.25^2 = 512, so corr ~ 128/640 = 0.2.
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum::<f32>()
            / (norm(&a) * norm(&b));
        assert!(dot > 0.15, "corr = {dot}");
        // And across *different* identities it is near zero.
        let c = identity_image(4242, 0, 0.25);
        let cross: f32 = a.iter().zip(&c).map(|(x, y)| x * y).sum::<f32>()
            / (norm(&a) * norm(&c));
        assert!(cross.abs() < 0.1, "cross = {cross}");
    }

    fn norm(v: &[f32]) -> f32 {
        v.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    #[test]
    fn gallery_matches_uncached_generation() {
        let mut gal = IdentityGallery::new();
        assert_eq!(gal.embedding(7), identity_embedding(7).as_slice());
        assert_eq!(gal.len(), 1);
        let mut buf = Vec::new();
        gal.image_into(9, 3, 0.25, &mut buf);
        assert_eq!(buf, identity_image(9, 3, 0.25));
        // Buffer reuse across identities/frames leaks nothing.
        gal.image_into(7, 0, 0.25, &mut buf);
        assert_eq!(buf, identity_image(7, 0, 0.25));
        assert_eq!(buf.len(), IMG_DIM);
        assert_eq!(gal.len(), 2);
    }
}
