//! Skewed device clocks (§4.6.2).
//!
//! Devices in a MAN/WAN have unsynchronized clocks. The paper's tuning
//! math is resilient to per-device skews as long as the clocks of the
//! devices hosting the *source* and *sink* tasks agree (κ1 = κn). We
//! model a signed skew per node; every timestamp a task records is the
//! true simulation time plus its node's skew. Tests assert the drop and
//! batch decisions are invariant to the skews.

use crate::util::{millis, rng, Micros};

/// Per-node clock skews relative to true time.
#[derive(Debug, Clone)]
pub struct ClockSkews {
    skews: Vec<Micros>,
}

impl ClockSkews {
    /// No skew anywhere (synchronized clocks).
    pub fn zero(nodes: usize) -> Self {
        Self {
            skews: vec![0; nodes],
        }
    }

    /// Random skews in `[-bound_ms, bound_ms]` for every node except the
    /// source and sink nodes (κ1 = κn = 0, the paper's §4.6.2 condition).
    pub fn random(
        nodes: usize,
        bound_ms: f64,
        source_node: usize,
        sink_node: usize,
        seed: u64,
    ) -> Self {
        let mut r = rng(seed, 0xC10C);
        let bound = millis(bound_ms);
        let skews = (0..nodes)
            .map(|n| {
                if n == source_node || n == sink_node || bound == 0 {
                    0
                } else {
                    r.range_i64(-bound, bound)
                }
            })
            .collect();
        Self { skews }
    }

    /// The time node `n`'s clock shows when true time is `t`.
    pub fn observe(&self, node: usize, t: Micros) -> Micros {
        t + self.skews[node]
    }

    pub fn skew(&self, node: usize) -> Micros {
        self.skews[node]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_skew_is_identity() {
        let c = ClockSkews::zero(4);
        assert_eq!(c.observe(2, 12345), 12345);
    }

    #[test]
    fn source_and_sink_never_skewed() {
        let c = ClockSkews::random(8, 500.0, 0, 7, 42);
        assert_eq!(c.skew(0), 0);
        assert_eq!(c.skew(7), 0);
        // At least one interior node should be skewed with this seed.
        assert!((1..7).any(|n| c.skew(n) != 0));
    }

    #[test]
    fn skews_bounded() {
        let c = ClockSkews::random(20, 100.0, 0, 19, 7);
        for n in 0..20 {
            assert!(c.skew(n).abs() <= millis(100.0));
        }
    }

    #[test]
    fn deterministic() {
        let a = ClockSkews::random(8, 500.0, 0, 7, 42);
        let b = ClockSkews::random(8, 500.0, 0, 7, 42);
        for n in 0..8 {
            assert_eq!(a.skew(n), b.skew(n));
        }
    }
}
